//! TOML-subset parser: `[section]` headers and `key = value` lines where a
//! value is a quoted string, integer, float, or bool. Comments with `#`.
//! Flat two-level structure (enough for serving configs; nested tables are
//! rejected loudly).

use std::collections::BTreeMap;

/// `section.key -> raw value` document.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ConfigDoc {
    /// Keys are `"section.key"`; top-level keys have no prefix.
    values: BTreeMap<String, Value>,
}

/// A parsed config value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

/// Config errors carry line numbers.
#[derive(Debug)]
pub enum ConfigError {
    Parse { line: usize, msg: String },
    Missing(String),
    Type { key: String, expected: &'static str, got: String },
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Parse { line, msg } => write!(f, "config line {line}: {msg}"),
            ConfigError::Missing(key) => write!(f, "missing key '{key}'"),
            ConfigError::Type { key, expected, got } => {
                write!(f, "key '{key}': expected {expected}, got {got}")
            }
        }
    }
}

impl std::error::Error for ConfigError {}

impl ConfigDoc {
    pub fn parse(text: &str) -> Result<ConfigDoc, ConfigError> {
        let mut doc = ConfigDoc::default();
        let mut section = String::new();
        for (i, raw) in text.lines().enumerate() {
            let line_no = i + 1;
            let line = match raw.find('#') {
                Some(pos) if !in_string(raw, pos) => &raw[..pos],
                _ => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| ConfigError::Parse {
                        line: line_no,
                        msg: "unterminated section header".into(),
                    })?
                    .trim();
                if name.contains('[') || name.contains('.') {
                    return Err(ConfigError::Parse {
                        line: line_no,
                        msg: format!("nested tables not supported: '{name}'"),
                    });
                }
                section = name.to_string();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| ConfigError::Parse {
                line: line_no,
                msg: format!("expected 'key = value', got '{line}'"),
            })?;
            let key = line[..eq].trim();
            let value = parse_value(line[eq + 1..].trim()).map_err(|msg| {
                ConfigError::Parse { line: line_no, msg }
            })?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            doc.values.insert(full, value);
        }
        Ok(doc)
    }

    /// Apply a `key=value` override (CLI `--set section.key=value`).
    pub fn set_override(&mut self, spec: &str) -> Result<(), ConfigError> {
        let eq = spec.find('=').ok_or_else(|| ConfigError::Parse {
            line: 0,
            msg: format!("override must be key=value, got '{spec}'"),
        })?;
        let key = spec[..eq].trim().to_string();
        let value = parse_value(spec[eq + 1..].trim())
            .map_err(|msg| ConfigError::Parse { line: 0, msg })?;
        self.values.insert(key, value);
        Ok(())
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }

    pub fn get_str(&self, key: &str) -> Option<String> {
        match self.values.get(key) {
            Some(Value::Str(s)) => Some(s.clone()),
            Some(Value::Int(i)) => Some(i.to_string()),
            Some(Value::Float(f)) => Some(f.to_string()),
            Some(Value::Bool(b)) => Some(b.to_string()),
            None => None,
        }
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>, ConfigError> {
        match self.values.get(key) {
            None => Ok(None),
            Some(Value::Int(i)) if *i >= 0 => Ok(Some(*i as usize)),
            Some(v) => Err(ConfigError::Type {
                key: key.into(),
                expected: "non-negative integer",
                got: format!("{v:?}"),
            }),
        }
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>, ConfigError> {
        match self.values.get(key) {
            None => Ok(None),
            Some(Value::Float(f)) => Ok(Some(*f)),
            Some(Value::Int(i)) => Ok(Some(*i as f64)),
            Some(v) => Err(ConfigError::Type {
                key: key.into(),
                expected: "number",
                got: format!("{v:?}"),
            }),
        }
    }

    pub fn get_bool(&self, key: &str) -> Result<Option<bool>, ConfigError> {
        match self.values.get(key) {
            None => Ok(None),
            Some(Value::Bool(b)) => Ok(Some(*b)),
            Some(v) => Err(ConfigError::Type {
                key: key.into(),
                expected: "bool",
                got: format!("{v:?}"),
            }),
        }
    }
}

fn in_string(line: &str, pos: usize) -> bool {
    line[..pos].bytes().filter(|&b| b == b'"').count() % 2 == 1
}

fn parse_value(text: &str) -> Result<Value, String> {
    if text.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = text.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    match text {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = text.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = text.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value '{text}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = ConfigDoc::parse(
            r#"
            # top comment
            name = "svc"        # trailing comment
            [code]
            k = 8
            s = 1
            [workers]
            latency = "exp:5"
            rate = 0.25
            enabled = true
            "#,
        )
        .unwrap();
        assert_eq!(doc.get_str("name").unwrap(), "svc");
        assert_eq!(doc.get_usize("code.k").unwrap(), Some(8));
        assert_eq!(doc.get_str("workers.latency").unwrap(), "exp:5");
        assert_eq!(doc.get_f64("workers.rate").unwrap(), Some(0.25));
        assert_eq!(doc.get_bool("workers.enabled").unwrap(), Some(true));
        assert_eq!(doc.get_usize("code.missing").unwrap(), None);
    }

    #[test]
    fn type_errors_are_descriptive() {
        let doc = ConfigDoc::parse("k = \"eight\"").unwrap();
        let err = doc.get_usize("k").unwrap_err();
        assert!(format!("{err}").contains("expected non-negative integer"));
    }

    #[test]
    fn parse_errors_carry_line_numbers() {
        let err = ConfigDoc::parse("a = 1\nbad line\n").unwrap_err();
        assert!(format!("{err}").contains("line 2"));
    }

    #[test]
    fn overrides_win() {
        let mut doc = ConfigDoc::parse("[code]\nk = 8\n").unwrap();
        doc.set_override("code.k=12").unwrap();
        assert_eq!(doc.get_usize("code.k").unwrap(), Some(12));
        assert!(doc.set_override("no-equals").is_err());
    }

    #[test]
    fn rejects_nested_tables() {
        assert!(ConfigDoc::parse("[a.b]\nk = 1\n").is_err());
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let doc = ConfigDoc::parse("tag = \"a#b\"\n").unwrap();
        assert_eq!(doc.get_str("tag").unwrap(), "a#b");
    }

    #[test]
    fn negative_int_not_usize() {
        let doc = ConfigDoc::parse("x = -3\n").unwrap();
        assert!(doc.get_usize("x").is_err());
        assert_eq!(doc.get_f64("x").unwrap(), Some(-3.0));
    }
}
