//! Configuration system: a TOML-subset parser (sections, key = value with
//! strings/numbers/bools) plus the typed serving schema with defaults and
//! CLI overrides. No `serde`/`toml` crates in this environment.

pub mod parser;
pub mod schema;

pub use parser::{ConfigDoc, ConfigError};
pub use schema::{AppConfig, TenantsConfig, KNOWN_KEYS, TENANT_FIELDS};
