//! Typed application configuration: defaults ← config file ← `--set`
//! overrides, validated into the structures the coordinator consumes.

use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coding::{CodeParams, NerccTuning, VerifyPolicy};
use crate::coordinator::{
    AdaptiveConfig, AdmissionConfig, Priority, ShedPolicy, Strategy, TenantSpec,
};
use crate::sim::faults::FaultProfile;
use crate::workers::{FleetConfig, HealthConfig, LatencyModel};

use super::parser::ConfigDoc;

/// Every config key the repo accepts — the schema's single source of
/// truth. [`AppConfig::from_doc`] rejects any key outside this list, and
/// the `docs_knobs` integration test diffs it against the knob table in
/// `docs/OPERATIONS.md`, so key, code and handbook cannot drift apart.
pub const KNOWN_KEYS: &[&str] = &[
    "code.k",
    "code.s",
    "code.e",
    "serving.strategy",
    "serving.artifacts",
    "serving.bind",
    "serving.batch_deadline_ms",
    "serving.max_inflight",
    "serving.decode_threads",
    "serving.group_timeout_ms",
    "serving.slo_ms",
    "serving.verify_decode",
    "serving.verify_tol",
    "nercc.lambda_enc",
    "nercc.lambda_dec",
    "model.arch",
    "model.dataset",
    "adaptive.enabled",
    "adaptive.window",
    "adaptive.target_miss_rate",
    "adaptive.cooldown",
    "admission.enabled",
    "admission.queue_depth",
    "admission.shed_policy",
    "admission.priority",
    "workers.latency",
    "faults.profile",
    "faults.seed",
    "fleet.enabled",
    "fleet.bind",
    "fleet.workers",
    "fleet.spare_slots",
    "fleet.heartbeat_ms",
    "fleet.miss_threshold",
    "tenants.enabled",
    "tenants.capacity",
    "health.enabled",
    "health.quarantine_threshold",
    "health.decay",
    "health.conviction_weight",
    "health.error_weight",
    "health.straggle_weight",
    "health.heartbeat_weight",
    "health.probation_ms",
    "health.probation_passes",
    "health.emergency_verify_failures",
];

/// Fields accepted under a `tenants.<name>.` prefix. The `<name>` segment
/// is free-form, so these keys cannot live in [`KNOWN_KEYS`]; the schema
/// validates them with this whitelist instead.
pub const TENANT_FIELDS: &[&str] = &[
    "engine",
    "scheme",
    "k",
    "s",
    "e",
    "slo_ms",
    "priority",
    "queue_depth",
    "weight",
    "budget",
];

/// Multi-tenant serving (`tenants.*` namespace): per-tenant serving
/// contracts plus the shared fairness capacity, consumed by
/// [`crate::coordinator::TenantRegistry`].
#[derive(Clone, Debug)]
pub struct TenantsConfig {
    /// Global bound on in-flight groups across all tenants
    /// (`tenants.capacity`; defaults to the sum of tenant budgets).
    pub capacity: usize,
    /// Per-tenant specs in alphabetical name order — which is also the
    /// tenant tag order on the shared fleet.
    pub specs: Vec<TenantSpec>,
}

/// Fully resolved application config.
#[derive(Clone, Debug)]
pub struct AppConfig {
    /// Code parameters (K, S, E). Normally the configured triple verbatim;
    /// the one exception is a K=1, S=0, E=0 passthrough deployment
    /// (uncoded/parm), where S is stored as 1 to keep the coded-geometry
    /// invariant `N = K+S−1 >= 1` — those strategies ignore S, and the
    /// rewrite is logged. Report fault envelopes from the scheme
    /// (`stragglers_tolerated`/`byzantine_tolerated`), not from this
    /// triple.
    pub params: CodeParams,
    /// Serving strategy.
    pub strategy: Strategy,
    /// Hosted model architecture (must exist in the artifact manifest).
    pub arch: String,
    /// Dataset the model was trained on (selects the artifact + test set).
    pub dataset: String,
    /// Artifacts directory.
    pub artifacts: String,
    /// TCP bind address for `serve`.
    pub bind: String,
    /// Batching deadline (`serving.batch_deadline_ms`): a partial group
    /// closes — zero-padded to `K` — once its oldest query has waited this
    /// long, so a trickle workload never stalls waiting for a full group.
    pub batch_deadline: Duration,
    /// Groups that may be in flight (dispatched, undecoded) at once.
    pub max_inflight: usize,
    /// Threads in the coordinator's locate/decode pool.
    pub decode_threads: usize,
    /// Per-group collection deadline.
    pub group_timeout: Duration,
    /// Per-group latency SLO (`serving.slo_ms`): past this the reply
    /// router attempts a hedged early decode with the scheme's reduced
    /// quota. `None` disables hedging and the adaptive straggler loop.
    pub slo: Option<Duration>,
    /// Adaptive redundancy control plane (`adaptive.*` namespace); `None`
    /// when `adaptive.enabled` is unset/false.
    pub adaptive: Option<AdaptiveConfig>,
    /// Admission control (`admission.*` namespace): bounded ingress queue,
    /// priority classes and load shedding. `None` when `admission.enabled`
    /// is unset/false — the ingress queue is then unbounded and overload
    /// shows up as queueing delay instead of explicit backpressure.
    pub admission: Option<AdmissionConfig>,
    /// Worker latency model (same for all workers).
    pub worker_latency: LatencyModel,
    /// Remote worker fleet (`fleet.*` namespace): when set, `serve` binds
    /// a fleet listener and waits for `approxifer worker` processes to
    /// join instead of spawning in-process worker threads. `None` when
    /// `fleet.enabled` is unset/false.
    pub fleet: Option<FleetConfig>,
    /// Worker health plane (`health.*` namespace): per-slot suspicion
    /// scoring over decode-path and heartbeat evidence, quarantine with
    /// spare-backed slot replacement, and probation-based re-entry. `None`
    /// when `health.enabled` is unset/false — every slot then stays in the
    /// dispatch rotation no matter how often it's convicted. Tenants
    /// inherit this table verbatim (the plane guards the shared physical
    /// fleet, so it cannot differ per tenant).
    pub health: Option<HealthConfig>,
    /// Multi-tenant serving (`tenants.*` namespace): one shared fleet,
    /// one service pipeline per tenant, fairness-scheduled dispatch.
    /// `None` when `tenants.enabled` is unset/false — the server then
    /// runs the single default tenant described by the rest of the
    /// config.
    pub tenants: Option<TenantsConfig>,
    /// Named fault profile spec (see [`FaultProfile::parse`]): which
    /// workers crash / straggle / flake / corrupt, deterministically under
    /// `seed`. `None` = all honest.
    pub fault_profile: Option<String>,
    /// Verify every decoded group by re-encoding it at the decode set's
    /// evaluation points (escalating to the homogeneous locator and then a
    /// group redispatch on failure). Opt-in: the tolerance is calibrated on
    /// the linear mock engines; validate against a real nonlinear model's
    /// Berrut residuals before enabling in production.
    pub verify_decode: bool,
    /// Max allowed relative re-encode residual before escalation.
    pub verify_tol: f64,
    /// NeRCC ridge weights (`nercc.*` namespace). Applied wherever a
    /// `nercc` scheme is built — the global strategy or any tenant whose
    /// `scheme = "nercc"`; every other strategy ignores them, so they are
    /// always present (defaulted) rather than gated behind a switch.
    pub nercc: NerccTuning,
    /// RNG seed for fault injection.
    pub seed: u64,
}

impl Default for AppConfig {
    fn default() -> Self {
        AppConfig {
            params: CodeParams::new(8, 1, 0),
            strategy: Strategy::ApproxIfer,
            arch: "resnet18_s".into(),
            dataset: "syncifar".into(),
            artifacts: "artifacts".into(),
            bind: "127.0.0.1:7700".into(),
            batch_deadline: Duration::from_millis(20),
            max_inflight: 4,
            decode_threads: 2,
            group_timeout: Duration::from_secs(30),
            slo: None,
            adaptive: None,
            admission: None,
            worker_latency: LatencyModel::None,
            fleet: None,
            health: None,
            tenants: None,
            fault_profile: None,
            verify_decode: false,
            verify_tol: 0.4,
            nercc: NerccTuning::default(),
            seed: 0xA11CE,
        }
    }
}

impl AppConfig {
    /// Build from an optional config file plus `--set key=value` overrides.
    pub fn load(path: Option<&str>, overrides: &[String]) -> Result<AppConfig> {
        let mut doc = match path {
            Some(p) => {
                let text = std::fs::read_to_string(p)
                    .with_context(|| format!("reading config file {p}"))?;
                ConfigDoc::parse(&text).with_context(|| format!("parsing {p}"))?
            }
            None => ConfigDoc::default(),
        };
        for ov in overrides {
            doc.set_override(ov).with_context(|| format!("applying override '{ov}'"))?;
        }
        AppConfig::from_doc(&doc)
    }

    pub fn from_doc(doc: &ConfigDoc) -> Result<AppConfig> {
        // The stochastic per-group knobs were replaced by named fault
        // profiles; fail loudly so an old config doesn't silently run an
        // all-honest fleet and report perfect robustness.
        for retired in ["faults.straggler_rate", "faults.straggler_delay_ms", "faults.byzantine"]
        {
            if doc.get_str(retired).is_some() {
                bail!(
                    "config key '{retired}' was retired; express the fault fleet as \
                     faults.profile (e.g. \"slow:1:0:40:0.5\" or \"byz-random:2:10\")"
                );
            }
        }
        if doc.get_str("serving.flush_after_ms").is_some() {
            bail!(
                "config key 'serving.flush_after_ms' was renamed; set \
                 serving.batch_deadline_ms (same meaning: a partial group closes \
                 after this many milliseconds)"
            );
        }
        // Reject unknown keys outright: a typo'd knob that silently falls
        // back to its default is the worst failure mode a config can have.
        // `tenants.<name>.<field>` keys carry a free-form name segment, so
        // they bypass the static list here and are validated against the
        // [`TENANT_FIELDS`] whitelist in the tenants block below.
        for key in doc.keys() {
            if key.starts_with("tenants.") {
                continue;
            }
            if !KNOWN_KEYS.contains(&key) {
                bail!(
                    "unknown config key '{key}' (see docs/OPERATIONS.md for the \
                     full knob table)"
                );
            }
        }
        let mut cfg = AppConfig::default();
        let k = doc.get_usize("code.k")?.unwrap_or(cfg.params.k);
        let s = doc.get_usize("code.s")?.unwrap_or(cfg.params.s);
        let e = doc.get_usize("code.e")?.unwrap_or(cfg.params.e);
        if k == 0 {
            bail!("code.k must be >= 1");
        }
        if let Some(v) = doc.get_str("serving.strategy") {
            cfg.strategy = Strategy::parse(&v).map_err(|e| anyhow::anyhow!(e))?;
        }
        // The coded strategies exist to tolerate faults; an (S=0, E=0)
        // ApproxIFER or replication deployment is a misconfiguration. The
        // passthrough baselines tolerate nothing by design.
        if e == 0 && s == 0 && !matches!(cfg.strategy, Strategy::Uncoded | Strategy::ParmProxy) {
            bail!("code must tolerate something: set code.s or code.e > 0");
        }
        // CodeParams models the coded geometry (N = K+S−1 >= 1). Only the
        // passthrough baselines can reach here with K=1, S=0, E=0 — they
        // ignore S entirely, so store S=1 to keep the triple constructible
        // instead of rejecting a valid uncoded/parm deployment. Logged so
        // the stored triple never silently diverges from the file.
        let s_stored = if e == 0 && k + s < 2 { 1 } else { s };
        if s_stored != s {
            log::warn!(
                "code.s stored as {s_stored} (configured {s}): K=1 passthrough deployments \
                 need a constructible code triple; the {:?} strategy ignores S",
                cfg.strategy
            );
        }
        cfg.params = CodeParams::new(k, s_stored, e);
        if let Some(v) = doc.get_str("model.arch") {
            cfg.arch = v;
        }
        if let Some(v) = doc.get_str("model.dataset") {
            cfg.dataset = v;
        }
        if let Some(v) = doc.get_str("serving.artifacts") {
            cfg.artifacts = v;
        }
        if let Some(v) = doc.get_str("serving.bind") {
            cfg.bind = v;
        }
        if let Some(ms) = doc.get_f64("serving.batch_deadline_ms")? {
            if ms <= 0.0 {
                bail!("serving.batch_deadline_ms must be positive");
            }
            cfg.batch_deadline = Duration::from_secs_f64(ms / 1e3);
        }
        if let Some(v) = doc.get_usize("serving.max_inflight")? {
            if v == 0 {
                bail!("serving.max_inflight must be >= 1");
            }
            cfg.max_inflight = v;
        }
        if let Some(v) = doc.get_usize("serving.decode_threads")? {
            if v == 0 {
                bail!("serving.decode_threads must be >= 1");
            }
            cfg.decode_threads = v;
        }
        if let Some(ms) = doc.get_f64("serving.group_timeout_ms")? {
            if ms <= 0.0 {
                bail!("serving.group_timeout_ms must be positive");
            }
            cfg.group_timeout = Duration::from_secs_f64(ms / 1e3);
        }
        if let Some(ms) = doc.get_f64("serving.slo_ms")? {
            if ms <= 0.0 {
                bail!("serving.slo_ms must be positive");
            }
            let slo = Duration::from_secs_f64(ms / 1e3);
            if slo >= cfg.group_timeout {
                bail!(
                    "serving.slo_ms ({ms}) must be shorter than serving.group_timeout_ms \
                     ({}) — the hedge deadline precedes the hard deadline",
                    cfg.group_timeout.as_secs_f64() * 1e3
                );
            }
            cfg.slo = Some(slo);
        }
        if doc.get_bool("adaptive.enabled")?.unwrap_or(false) {
            let mut adaptive = AdaptiveConfig::default();
            if let Some(w) = doc.get_usize("adaptive.window")? {
                if w == 0 {
                    bail!("adaptive.window must be >= 1");
                }
                adaptive.window = w;
            }
            if let Some(r) = doc.get_f64("adaptive.target_miss_rate")? {
                if !(0.0..1.0).contains(&r) {
                    bail!("adaptive.target_miss_rate must be in [0, 1), got {r}");
                }
                adaptive.target_miss_rate = r;
            }
            if let Some(c) = doc.get_usize("adaptive.cooldown")? {
                if c == 0 {
                    bail!("adaptive.cooldown must be >= 1");
                }
                adaptive.cooldown = c;
            }
            cfg.adaptive = Some(adaptive);
        } else {
            // Refuse sub-keys without the master switch: a config that
            // tunes a disabled controller is a footgun, not a no-op.
            for key in ["adaptive.window", "adaptive.target_miss_rate", "adaptive.cooldown"] {
                if doc.get_str(key).is_some() {
                    bail!("'{key}' is set but adaptive.enabled is not true");
                }
            }
        }
        if doc.get_bool("admission.enabled")?.unwrap_or(false) {
            let mut admission = AdmissionConfig::default();
            if let Some(d) = doc.get_usize("admission.queue_depth")? {
                if d == 0 {
                    bail!("admission.queue_depth must be >= 1");
                }
                admission.queue_depth = d;
            }
            if let Some(p) = doc.get_str("admission.shed_policy") {
                admission.shed_policy = ShedPolicy::parse(&p)
                    .with_context(|| "admission.shed_policy".to_string())?;
            }
            if let Some(p) = doc.get_str("admission.priority") {
                admission.default_priority =
                    Priority::parse(&p).with_context(|| "admission.priority".to_string())?;
            }
            cfg.admission = Some(admission);
        } else {
            // Same rule as adaptive.*: tuning a disabled gate is a footgun,
            // not a no-op.
            for key in
                ["admission.queue_depth", "admission.shed_policy", "admission.priority"]
            {
                if doc.get_str(key).is_some() {
                    bail!("'{key}' is set but admission.enabled is not true");
                }
            }
        }
        if let Some(v) = doc.get_str("workers.latency") {
            cfg.worker_latency = LatencyModel::parse(&v).map_err(|e| anyhow::anyhow!(e))?;
        }
        if doc.get_bool("fleet.enabled")?.unwrap_or(false) {
            let mut fleet = FleetConfig::default();
            if let Some(v) = doc.get_str("fleet.bind") {
                fleet.bind = v;
            }
            if let Some(v) = doc.get_usize("fleet.workers")? {
                if v == 0 {
                    bail!("fleet.workers must be >= 1");
                }
                fleet.workers = Some(v);
            }
            if let Some(v) = doc.get_usize("fleet.spare_slots")? {
                fleet.spare_slots = v;
            }
            if let Some(ms) = doc.get_f64("fleet.heartbeat_ms")? {
                if ms <= 0.0 {
                    bail!("fleet.heartbeat_ms must be positive");
                }
                fleet.heartbeat = Duration::from_secs_f64(ms / 1e3);
            }
            if let Some(v) = doc.get_usize("fleet.miss_threshold")? {
                if v == 0 {
                    bail!("fleet.miss_threshold must be >= 1");
                }
                fleet.miss_threshold = v as u32;
            }
            cfg.fleet = Some(fleet);
        } else {
            // Same rule as adaptive.*/admission.*: tuning a disabled fleet
            // listener is a footgun, not a no-op.
            for key in [
                "fleet.bind",
                "fleet.workers",
                "fleet.spare_slots",
                "fleet.heartbeat_ms",
                "fleet.miss_threshold",
            ] {
                if doc.get_str(key).is_some() {
                    bail!("'{key}' is set but fleet.enabled is not true");
                }
            }
        }
        if doc.get_bool("health.enabled")?.unwrap_or(false) {
            let mut h = HealthConfig::default();
            if let Some(v) = doc.get_f64("health.quarantine_threshold")? {
                h.quarantine_threshold = v;
            }
            if let Some(v) = doc.get_f64("health.decay")? {
                h.decay = v;
            }
            if let Some(v) = doc.get_f64("health.conviction_weight")? {
                h.conviction_weight = v;
            }
            if let Some(v) = doc.get_f64("health.error_weight")? {
                h.error_weight = v;
            }
            if let Some(v) = doc.get_f64("health.straggle_weight")? {
                h.straggle_weight = v;
            }
            if let Some(v) = doc.get_f64("health.heartbeat_weight")? {
                h.heartbeat_weight = v;
            }
            if let Some(v) = doc.get_usize("health.probation_ms")? {
                h.probation_ms = v as u64;
            }
            if let Some(v) = doc.get_usize("health.probation_passes")? {
                h.probation_passes = v;
            }
            if let Some(v) = doc.get_usize("health.emergency_verify_failures")? {
                h.emergency_verify_failures = v;
            }
            // Range semantics (threshold > 0, decay in [0,1), weights >= 0,
            // probation_passes/emergency >= 1) live in one place: the
            // plane's own validator.
            h.validate().context("health.* config")?;
            cfg.health = Some(h);
        } else {
            // Same rule as adaptive.*/admission.*/fleet.*: tuning a
            // disabled health plane is a footgun, not a no-op.
            for key in [
                "health.quarantine_threshold",
                "health.decay",
                "health.conviction_weight",
                "health.error_weight",
                "health.straggle_weight",
                "health.heartbeat_weight",
                "health.probation_ms",
                "health.probation_passes",
                "health.emergency_verify_failures",
            ] {
                if doc.get_str(key).is_some() {
                    bail!("'{key}' is set but health.enabled is not true");
                }
            }
        }
        if let Some(v) = doc.get_bool("serving.verify_decode")? {
            cfg.verify_decode = v;
        }
        if let Some(v) = doc.get_f64("serving.verify_tol")? {
            if v <= 0.0 {
                bail!("serving.verify_tol must be positive, got {v}");
            }
            cfg.verify_tol = v;
        }
        // NeRCC ridge weights: strictly positive (a zero ridge would let
        // the regression Gram systems go singular on degenerate point
        // subsets). Accepted regardless of the global strategy — a tenant
        // table may host a nercc scheme under any global default.
        if let Some(v) = doc.get_f64("nercc.lambda_enc")? {
            if v <= 0.0 {
                bail!("nercc.lambda_enc must be positive, got {v}");
            }
            cfg.nercc.lambda_enc = v;
        }
        if let Some(v) = doc.get_f64("nercc.lambda_dec")? {
            if v <= 0.0 {
                bail!("nercc.lambda_dec must be positive, got {v}");
            }
            cfg.nercc.lambda_dec = v;
        }
        // Hedged decodes and the adaptive Byzantine loop both lean on the
        // verification ladder; surface the spawn-time rule at config load
        // so the operator sees it before the fleet starts. (Checked here,
        // after every serving.*/adaptive.* knob above has been applied.)
        if (cfg.slo.is_some() || cfg.adaptive.is_some())
            && cfg.params.e > 0
            && !cfg.verify_decode
            && matches!(
                cfg.strategy,
                Strategy::ApproxIfer | Strategy::Nercc | Strategy::Replication
            )
        {
            bail!(
                "serving.slo_ms / adaptive.enabled with code.e > 0 requires \
                 serving.verify_decode = true (hedged decodes and the controller's \
                 Byzantine loop lean on the verification ladder)"
            );
        }
        if doc.get_bool("tenants.enabled")?.unwrap_or(false) {
            // Tenant names are discovered by prefix scan: every
            // `tenants.<name>.<field>` key declares (or extends) a tenant.
            // BTreeSet gives a deterministic alphabetical tag order.
            let mut names = std::collections::BTreeSet::new();
            for key in doc.keys() {
                let Some(rest) = key.strip_prefix("tenants.") else { continue };
                if rest == "enabled" || rest == "capacity" {
                    continue;
                }
                let Some((name, field)) = rest.split_once('.') else {
                    bail!(
                        "unknown config key '{key}' (tenant fields are \
                         tenants.<name>.<field>)"
                    );
                };
                if name.is_empty() || !TENANT_FIELDS.contains(&field) {
                    bail!(
                        "unknown tenant field in '{key}' (expected tenants.<name>.<field> \
                         with field one of {})",
                        TENANT_FIELDS.join("|")
                    );
                }
                names.insert(name.to_string());
            }
            if names.is_empty() {
                bail!(
                    "tenants.enabled = true but no tenants.<name>.<field> keys define \
                     any tenant"
                );
            }
            let mut specs = Vec::with_capacity(names.len());
            for name in &names {
                let mut spec = TenantSpec { name: name.clone(), ..TenantSpec::default() };
                let field = |f: &str| format!("tenants.{name}.{f}");
                if let Some(v) = doc.get_str(&field("engine")) {
                    spec.engine = v;
                }
                if let Some(v) = doc.get_str(&field("scheme")) {
                    spec.strategy = Strategy::parse(&v)
                        .map_err(|e| anyhow::anyhow!("tenants.{name}.scheme: {e}"))?;
                }
                let k = doc.get_usize(&field("k"))?.unwrap_or(spec.params.k);
                let s = doc.get_usize(&field("s"))?.unwrap_or(spec.params.s);
                let e = doc.get_usize(&field("e"))?.unwrap_or(spec.params.e);
                if k == 0 {
                    bail!("tenants.{name}.k must be >= 1");
                }
                // Same rules as the top-level code.* triple: coded
                // strategies must tolerate something, and a K=1
                // passthrough stores S=1 to keep the triple constructible.
                if e == 0
                    && s == 0
                    && !matches!(spec.strategy, Strategy::Uncoded | Strategy::ParmProxy)
                {
                    bail!("tenant '{name}': code must tolerate something — set s or e > 0");
                }
                let s_stored = if e == 0 && k + s < 2 { 1 } else { s };
                spec.params = CodeParams::new(k, s_stored, e);
                if let Some(ms) = doc.get_f64(&field("slo_ms"))? {
                    if ms <= 0.0 {
                        bail!("tenants.{name}.slo_ms must be positive");
                    }
                    spec.slo = Some(Duration::from_secs_f64(ms / 1e3));
                }
                if let Some(p) = doc.get_str(&field("priority")) {
                    spec.priority = Priority::parse(&p)
                        .with_context(|| format!("tenants.{name}.priority"))?;
                }
                if let Some(d) = doc.get_usize(&field("queue_depth"))? {
                    if d == 0 {
                        bail!("tenants.{name}.queue_depth must be >= 1");
                    }
                    spec.queue_depth = Some(d);
                }
                if let Some(w) = doc.get_usize(&field("weight"))? {
                    if w == 0 {
                        bail!("tenants.{name}.weight must be >= 1");
                    }
                    spec.weight = w as u64;
                }
                if let Some(b) = doc.get_usize(&field("budget"))? {
                    if b == 0 {
                        bail!("tenants.{name}.budget must be >= 1");
                    }
                    spec.budget = b;
                }
                // Tenants inherit the global serving policies that are
                // not per-tenant knobs (yet): verification, batching and
                // the hard group deadline.
                spec.verify = if cfg.verify_decode {
                    VerifyPolicy::on(cfg.verify_tol)
                } else {
                    VerifyPolicy::off()
                };
                spec.batch_deadline = cfg.batch_deadline;
                spec.group_timeout = cfg.group_timeout;
                spec.nercc = cfg.nercc;
                spec.health = cfg.health.clone();
                if spec.slo.is_some() && spec.params.e > 0 && !spec.verify.enabled {
                    bail!(
                        "tenants.{name}.slo_ms with e > 0 requires \
                         serving.verify_decode = true (hedged decodes lean on the \
                         verification ladder)"
                    );
                }
                if let Some(slo) = spec.slo {
                    if slo >= spec.group_timeout {
                        bail!(
                            "tenants.{name}.slo_ms must be shorter than \
                             serving.group_timeout_ms"
                        );
                    }
                }
                specs.push(spec);
            }
            let capacity = match doc.get_usize("tenants.capacity")? {
                Some(0) => bail!("tenants.capacity must be >= 1"),
                Some(c) => c,
                // Default: the sum of budgets — every tenant can reach its
                // own in-flight bound simultaneously.
                None => specs.iter().map(|s| s.budget).sum(),
            };
            cfg.tenants = Some(TenantsConfig { capacity, specs });
        } else {
            // Same rule as adaptive.*/admission.*/fleet.*: a tenant table
            // without the master switch is a footgun, not a no-op.
            for key in doc.keys() {
                if key.starts_with("tenants.") && key != "tenants.enabled" {
                    bail!("'{key}' is set but tenants.enabled is not true");
                }
            }
        }
        if let Some(v) = doc.get_usize("faults.seed")? {
            cfg.seed = v as u64;
        }
        if let Some(v) = doc.get_str("faults.profile") {
            // Validate eagerly so a typo fails at startup, not mid-serve.
            // Sized against the *strategy's* worker count — replication
            // fleets are larger than the ApproxIFER fleet for the same
            // (K,S,E), and a mis-sized profile must fail here, not panic
            // later.
            FaultProfile::parse(&v, cfg.strategy.num_workers(cfg.params), cfg.seed)
                .map_err(|e| anyhow::anyhow!("faults.profile: {e}"))?;
            cfg.fault_profile = Some(v);
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let cfg = AppConfig::load(None, &[]).unwrap();
        assert_eq!(cfg.params, CodeParams::new(8, 1, 0));
        assert_eq!(cfg.strategy, Strategy::ApproxIfer);
        assert_eq!(cfg.max_inflight, 4);
        assert_eq!(cfg.decode_threads, 2);
        assert_eq!(cfg.group_timeout, Duration::from_secs(30));
    }

    #[test]
    fn scheduler_knobs_parse_and_validate() {
        let doc = ConfigDoc::parse(
            r#"
            [serving]
            max_inflight = 8
            decode_threads = 3
            group_timeout_ms = 1500
            "#,
        )
        .unwrap();
        let cfg = AppConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.max_inflight, 8);
        assert_eq!(cfg.decode_threads, 3);
        assert_eq!(cfg.group_timeout, Duration::from_millis(1500));

        let doc = ConfigDoc::parse("[serving]\nmax_inflight = 0\n").unwrap();
        assert!(AppConfig::from_doc(&doc).is_err());
        let doc = ConfigDoc::parse("[serving]\ndecode_threads = 0\n").unwrap();
        assert!(AppConfig::from_doc(&doc).is_err());
        let doc = ConfigDoc::parse("[serving]\ngroup_timeout_ms = 0\n").unwrap();
        assert!(AppConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn nercc_knobs_parse_validate_and_inherit() {
        let doc = ConfigDoc::parse(
            r#"
            [serving]
            strategy = "nercc"
            [nercc]
            lambda_enc = 1e-4
            lambda_dec = 2e-5
            [tenants]
            enabled = true
            alpha.scheme = "nercc"
            alpha.k = 2
            alpha.s = 1
            "#,
        )
        .unwrap();
        let cfg = AppConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.strategy, Strategy::Nercc);
        assert!((cfg.nercc.lambda_enc - 1e-4).abs() < 1e-18);
        assert!((cfg.nercc.lambda_dec - 2e-5).abs() < 1e-18);
        // Tenants inherit the global ridge weights like the other
        // non-per-tenant serving policies.
        let t = cfg.tenants.expect("tenants enabled");
        assert_eq!(t.specs[0].nercc, cfg.nercc);

        for bad in ["lambda_enc = 0.0", "lambda_dec = -1e-6"] {
            let doc = ConfigDoc::parse(&format!("[nercc]\n{bad}\n")).unwrap();
            assert!(AppConfig::from_doc(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn adaptive_and_slo_knobs_parse() {
        let doc = ConfigDoc::parse(
            r#"
            [serving]
            slo_ms = 50
            [adaptive]
            enabled = true
            window = 16
            target_miss_rate = 0.02
            cooldown = 3
            "#,
        )
        .unwrap();
        let cfg = AppConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.slo, Some(Duration::from_millis(50)));
        let a = cfg.adaptive.expect("adaptive enabled");
        assert_eq!(a.window, 16);
        assert_eq!(a.cooldown, 3);
        assert!((a.target_miss_rate - 0.02).abs() < 1e-12);

        // Defaults apply when only the switch is set.
        let doc = ConfigDoc::parse("[adaptive]\nenabled = true\n").unwrap();
        let cfg = AppConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.adaptive.unwrap().window, 32);
        assert_eq!(cfg.slo, None);
    }

    #[test]
    fn adaptive_and_slo_invalid_values_rejected() {
        // The hedge deadline must undercut the hard deadline.
        let doc =
            ConfigDoc::parse("[serving]\ngroup_timeout_ms = 100\nslo_ms = 100\n").unwrap();
        assert!(AppConfig::from_doc(&doc).is_err());
        let doc = ConfigDoc::parse("[serving]\nslo_ms = 0\n").unwrap();
        assert!(AppConfig::from_doc(&doc).is_err());
        // Orphan adaptive keys without the master switch are refused.
        let doc = ConfigDoc::parse("[adaptive]\nwindow = 8\n").unwrap();
        let err = AppConfig::from_doc(&doc).unwrap_err();
        assert!(format!("{err:#}").contains("adaptive.enabled"), "{err:#}");
        // Out-of-range tuning fails at load time.
        let doc = ConfigDoc::parse("[adaptive]\nenabled = true\nwindow = 0\n").unwrap();
        assert!(AppConfig::from_doc(&doc).is_err());
        let doc =
            ConfigDoc::parse("[adaptive]\nenabled = true\ntarget_miss_rate = 1.5\n").unwrap();
        assert!(AppConfig::from_doc(&doc).is_err());
        let doc = ConfigDoc::parse("[adaptive]\nenabled = true\ncooldown = 0\n").unwrap();
        assert!(AppConfig::from_doc(&doc).is_err());
        // SLO + Byzantine budget without the verification safety net is
        // refused at load time (ordering-sensitive: verify_decode is set
        // in the same file).
        let doc = ConfigDoc::parse(
            "[code]\nk = 4\ns = 0\ne = 1\n[serving]\nslo_ms = 20\n",
        )
        .unwrap();
        let err = AppConfig::from_doc(&doc).unwrap_err();
        assert!(format!("{err:#}").contains("verify_decode"), "{err:#}");
        let doc = ConfigDoc::parse(
            "[code]\nk = 4\ns = 0\ne = 1\n[serving]\nslo_ms = 20\nverify_decode = true\n",
        )
        .unwrap();
        assert!(AppConfig::from_doc(&doc).is_ok());
        // Same rule for the adaptive controller's Byzantine loop.
        let doc = ConfigDoc::parse(
            "[code]\nk = 4\ns = 0\ne = 1\n[adaptive]\nenabled = true\n",
        )
        .unwrap();
        assert!(AppConfig::from_doc(&doc).is_err());
        let doc = ConfigDoc::parse(
            "[code]\nk = 4\ns = 0\ne = 1\n[adaptive]\nenabled = true\n\
             [serving]\nverify_decode = true\n",
        )
        .unwrap();
        assert!(AppConfig::from_doc(&doc).is_ok());
    }

    #[test]
    fn doc_and_overrides_apply() {
        let doc = ConfigDoc::parse(
            r#"
            [code]
            k = 12
            e = 2
            s = 0
            [serving]
            strategy = "replication"
            verify_decode = true
            verify_tol = 0.5
            [workers]
            latency = "exp:4"
            [faults]
            profile = "byz-random:2:10"
            seed = 99
            "#,
        )
        .unwrap();
        let cfg = AppConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.params, CodeParams::new(12, 0, 2));
        assert_eq!(cfg.strategy, Strategy::Replication);
        assert_eq!(cfg.worker_latency, LatencyModel::Exponential { mean_ms: 4.0 });
        assert_eq!(cfg.fault_profile.as_deref(), Some("byz-random:2:10"));
        assert!(cfg.verify_decode);
        assert_eq!(cfg.verify_tol, 0.5);
        assert_eq!(cfg.seed, 99);
        // The stored spec expands deterministically for this deployment.
        let p = FaultProfile::parse(
            cfg.fault_profile.as_deref().unwrap(),
            cfg.params.num_workers(),
            cfg.seed,
        )
        .unwrap();
        assert_eq!(p.faulty().len(), 2);
    }

    #[test]
    fn invalid_values_rejected() {
        let doc = ConfigDoc::parse("[code]\nk = 0\n").unwrap();
        assert!(AppConfig::from_doc(&doc).is_err());
        let doc = ConfigDoc::parse("[code]\ns = 0\ne = 0\n").unwrap();
        assert!(AppConfig::from_doc(&doc).is_err());
        let doc = ConfigDoc::parse("[serving]\nverify_tol = 0\n").unwrap();
        assert!(AppConfig::from_doc(&doc).is_err());
        // Unknown profile names and over-large counts fail at load time.
        let doc = ConfigDoc::parse("[faults]\nprofile = \"nonsense:3\"\n").unwrap();
        assert!(AppConfig::from_doc(&doc).is_err());
        let doc = ConfigDoc::parse("[faults]\nprofile = \"crash:99@4\"\n").unwrap();
        assert!(AppConfig::from_doc(&doc).is_err());
        // Retired stochastic fault knobs are rejected, not silently ignored.
        for retired in
            ["straggler_rate = 0.5", "straggler_delay_ms = 100", "byzantine = \"gauss:10\""]
        {
            let doc = ConfigDoc::parse(&format!("[faults]\n{retired}\n")).unwrap();
            let err = AppConfig::from_doc(&doc).unwrap_err();
            assert!(format!("{err:#}").contains("retired"), "{retired}: {err:#}");
        }
    }

    #[test]
    fn cli_override_beats_file_value() {
        let cfg = AppConfig::load(None, &["code.k=10".to_string()]).unwrap();
        assert_eq!(cfg.params.k, 10);
    }

    #[test]
    fn batch_deadline_parses_and_old_spelling_is_retired() {
        let doc = ConfigDoc::parse("[serving]\nbatch_deadline_ms = 5\n").unwrap();
        let cfg = AppConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.batch_deadline, Duration::from_millis(5));

        let doc = ConfigDoc::parse("[serving]\nbatch_deadline_ms = 0\n").unwrap();
        assert!(AppConfig::from_doc(&doc).is_err());

        let doc = ConfigDoc::parse("[serving]\nflush_after_ms = 5\n").unwrap();
        let err = AppConfig::from_doc(&doc).unwrap_err();
        assert!(format!("{err:#}").contains("batch_deadline_ms"), "{err:#}");
    }

    #[test]
    fn admission_knobs_parse_and_gate() {
        let doc = ConfigDoc::parse(
            r#"
            [admission]
            enabled = true
            queue_depth = 256
            shed_policy = "shed:batch"
            priority = "batch"
            "#,
        )
        .unwrap();
        let cfg = AppConfig::from_doc(&doc).unwrap();
        let a = cfg.admission.expect("admission enabled");
        assert_eq!(a.queue_depth, 256);
        assert_eq!(a.shed_policy, ShedPolicy::ShedBatch);
        assert_eq!(a.default_priority, Priority::Batch);

        // Defaults apply when only the switch is set.
        let doc = ConfigDoc::parse("[admission]\nenabled = true\n").unwrap();
        let a = AppConfig::from_doc(&doc).unwrap().admission.unwrap();
        assert_eq!(a.queue_depth, 1024);
        assert_eq!(a.shed_policy, ShedPolicy::Reject);
        assert_eq!(a.default_priority, Priority::Interactive);

        // Orphan sub-keys without the master switch are refused.
        let doc = ConfigDoc::parse("[admission]\nqueue_depth = 64\n").unwrap();
        let err = AppConfig::from_doc(&doc).unwrap_err();
        assert!(format!("{err:#}").contains("admission.enabled"), "{err:#}");

        // Out-of-range / unparseable values fail at load time.
        let doc = ConfigDoc::parse("[admission]\nenabled = true\nqueue_depth = 0\n").unwrap();
        assert!(AppConfig::from_doc(&doc).is_err());
        let doc = ConfigDoc::parse(
            "[admission]\nenabled = true\nshed_policy = \"drop-everything\"\n",
        )
        .unwrap();
        assert!(AppConfig::from_doc(&doc).is_err());
        let doc =
            ConfigDoc::parse("[admission]\nenabled = true\npriority = \"bulk\"\n").unwrap();
        assert!(AppConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn fleet_knobs_parse_and_gate() {
        let doc = ConfigDoc::parse(
            r#"
            [fleet]
            enabled = true
            bind = "0.0.0.0:7801"
            workers = 12
            spare_slots = 2
            heartbeat_ms = 250
            miss_threshold = 5
            "#,
        )
        .unwrap();
        let cfg = AppConfig::from_doc(&doc).unwrap();
        let f = cfg.fleet.expect("fleet enabled");
        assert_eq!(f.bind, "0.0.0.0:7801");
        assert_eq!(f.workers, Some(12));
        assert_eq!(f.spare_slots, 2);
        assert_eq!(f.heartbeat, Duration::from_millis(250));
        assert_eq!(f.miss_threshold, 5);

        // Defaults apply when only the switch is set; the slot count then
        // follows the scheme's worker need at serve time.
        let doc = ConfigDoc::parse("[fleet]\nenabled = true\n").unwrap();
        let f = AppConfig::from_doc(&doc).unwrap().fleet.unwrap();
        assert_eq!(f.bind, "127.0.0.1:7800");
        assert_eq!(f.workers, None);
        assert_eq!(f.spare_slots, 0);
        assert_eq!(f.heartbeat, Duration::from_millis(500));
        assert_eq!(f.miss_threshold, 3);

        // Orphan sub-keys without the master switch are refused.
        let doc = ConfigDoc::parse("[fleet]\nbind = \"0.0.0.0:7801\"\n").unwrap();
        let err = AppConfig::from_doc(&doc).unwrap_err();
        assert!(format!("{err:#}").contains("fleet.enabled"), "{err:#}");

        // Out-of-range values fail at load time.
        for bad in ["workers = 0", "heartbeat_ms = 0", "miss_threshold = 0"] {
            let doc =
                ConfigDoc::parse(&format!("[fleet]\nenabled = true\n{bad}\n")).unwrap();
            assert!(AppConfig::from_doc(&doc).is_err(), "{bad} should be rejected");
        }
    }

    #[test]
    fn tenant_table_parses_with_defaults_and_overrides() {
        let doc = ConfigDoc::parse(
            r#"
            [tenants]
            enabled = true
            capacity = 6
            alpha.engine = "mock:8:3"
            alpha.scheme = "approxifer"
            alpha.k = 2
            alpha.s = 1
            alpha.weight = 3
            alpha.budget = 2
            beta.engine = "mock:8:5"
            beta.scheme = "replication"
            beta.k = 2
            beta.s = 1
            beta.priority = "batch"
            beta.queue_depth = 64
            "#,
        )
        .unwrap();
        let cfg = AppConfig::from_doc(&doc).unwrap();
        let t = cfg.tenants.expect("tenants enabled");
        assert_eq!(t.capacity, 6);
        assert_eq!(t.specs.len(), 2);
        // Specs come out in alphabetical name order — the tag order.
        assert_eq!(t.specs[0].name, "alpha");
        assert_eq!(t.specs[0].engine, "mock:8:3");
        assert_eq!(t.specs[0].params, CodeParams::new(2, 1, 0));
        assert_eq!(t.specs[0].weight, 3);
        assert_eq!(t.specs[0].budget, 2);
        assert_eq!(t.specs[1].name, "beta");
        assert_eq!(t.specs[1].strategy, Strategy::Replication);
        assert_eq!(t.specs[1].priority, Priority::Batch);
        assert_eq!(t.specs[1].queue_depth, Some(64));
        // Unset capacity defaults to the sum of budgets.
        let doc = ConfigDoc::parse(
            "[tenants]\nenabled = true\nalpha.budget = 3\nbeta.budget = 2\n",
        )
        .unwrap();
        let t = AppConfig::from_doc(&doc).unwrap().tenants.unwrap();
        assert_eq!(t.capacity, 5);
        // Tenants inherit the global verification policy.
        let doc = ConfigDoc::parse(
            "[serving]\nverify_decode = true\nverify_tol = 0.5\n\
             [tenants]\nenabled = true\nalpha.k = 2\n",
        )
        .unwrap();
        let t = AppConfig::from_doc(&doc).unwrap().tenants.unwrap();
        assert!(t.specs[0].verify.enabled);
        assert_eq!(t.specs[0].verify.tol, 0.5);
    }

    #[test]
    fn tenant_keys_gate_on_enabled_and_bad_fields_fail() {
        // Orphan tenant keys without the master switch are refused.
        let doc = ConfigDoc::parse("[tenants]\nalpha.k = 4\n").unwrap();
        let err = AppConfig::from_doc(&doc).unwrap_err();
        assert!(format!("{err:#}").contains("tenants.enabled"), "{err:#}");
        // Unknown tenant fields fail against the whitelist.
        let doc =
            ConfigDoc::parse("[tenants]\nenabled = true\nalpha.k = 4\nalpha.burst = 9\n")
                .unwrap();
        let err = AppConfig::from_doc(&doc).unwrap_err();
        assert!(format!("{err:#}").contains("unknown tenant field"), "{err:#}");
        // A bare tenants key that is neither a switch nor a field table.
        let doc = ConfigDoc::parse("[tenants]\nenabled = true\nbogus = 1\n").unwrap();
        assert!(AppConfig::from_doc(&doc).is_err());
        // The switch without any tenant definitions is a misconfiguration.
        let doc = ConfigDoc::parse("[tenants]\nenabled = true\n").unwrap();
        let err = AppConfig::from_doc(&doc).unwrap_err();
        assert!(format!("{err:#}").contains("define any tenant"), "{err:#}");
        // Zero-valued tenant knobs fail at load time.
        for bad in
            ["alpha.k = 0", "alpha.weight = 0", "alpha.budget = 0", "alpha.queue_depth = 0"]
        {
            let doc =
                ConfigDoc::parse(&format!("[tenants]\nenabled = true\n{bad}\n")).unwrap();
            assert!(AppConfig::from_doc(&doc).is_err(), "{bad} should be rejected");
        }
        // Per-tenant Byzantine budgets need the shared verification ladder
        // once the tenant hedges under an SLO.
        let doc = ConfigDoc::parse(
            "[tenants]\nenabled = true\nalpha.k = 2\nalpha.s = 0\nalpha.e = 1\n\
             alpha.slo_ms = 20\n",
        )
        .unwrap();
        let err = AppConfig::from_doc(&doc).unwrap_err();
        assert!(format!("{err:#}").contains("verify_decode"), "{err:#}");
    }

    #[test]
    fn health_knobs_parse_gate_and_inherit() {
        let doc = ConfigDoc::parse(
            r#"
            [health]
            enabled = true
            quarantine_threshold = 4.5
            decay = 0.9
            conviction_weight = 3.0
            error_weight = 0.5
            straggle_weight = 0.1
            heartbeat_weight = 2.0
            probation_ms = 400
            probation_passes = 3
            emergency_verify_failures = 5
            "#,
        )
        .unwrap();
        let cfg = AppConfig::from_doc(&doc).unwrap();
        let h = cfg.health.expect("health enabled");
        assert_eq!(h.quarantine_threshold, 4.5);
        assert_eq!(h.decay, 0.9);
        assert_eq!(h.conviction_weight, 3.0);
        assert_eq!(h.error_weight, 0.5);
        assert_eq!(h.straggle_weight, 0.1);
        assert_eq!(h.heartbeat_weight, 2.0);
        assert_eq!(h.probation_ms, 400);
        assert_eq!(h.probation_passes, 3);
        assert_eq!(h.emergency_verify_failures, 5);

        // Defaults apply when only the switch is set.
        let doc = ConfigDoc::parse("[health]\nenabled = true\n").unwrap();
        let h = AppConfig::from_doc(&doc).unwrap().health.unwrap();
        assert_eq!(h, HealthConfig::default());

        // Orphan sub-keys without the master switch are refused.
        let doc = ConfigDoc::parse("[health]\ndecay = 0.5\n").unwrap();
        let err = AppConfig::from_doc(&doc).unwrap_err();
        assert!(format!("{err:#}").contains("health.enabled"), "{err:#}");

        // Out-of-range values fail at load time through the plane's own
        // validator.
        for bad in [
            "quarantine_threshold = 0",
            "decay = 1.0",
            "decay = -0.1",
            "conviction_weight = -1.0",
            "probation_passes = 0",
            "emergency_verify_failures = 0",
        ] {
            let doc =
                ConfigDoc::parse(&format!("[health]\nenabled = true\n{bad}\n")).unwrap();
            assert!(AppConfig::from_doc(&doc).is_err(), "{bad} should be rejected");
        }

        // Tenants inherit the shared plane's table verbatim.
        let doc = ConfigDoc::parse(
            "[health]\nenabled = true\nquarantine_threshold = 5.0\n\
             [tenants]\nenabled = true\nalpha.k = 2\nalpha.s = 1\n",
        )
        .unwrap();
        let cfg = AppConfig::from_doc(&doc).unwrap();
        let t = cfg.tenants.expect("tenants enabled");
        assert_eq!(t.specs[0].health, cfg.health);
        assert_eq!(t.specs[0].health.as_ref().unwrap().quarantine_threshold, 5.0);
    }

    #[test]
    fn unknown_keys_are_rejected() {
        let doc = ConfigDoc::parse("[serving]\nflish_after_ms = 5\n").unwrap();
        let err = AppConfig::from_doc(&doc).unwrap_err();
        assert!(format!("{err:#}").contains("unknown config key"), "{err:#}");
        let err = AppConfig::load(None, &["serving.stratgy=uncoded".into()]).unwrap_err();
        assert!(format!("{err:#}").contains("unknown config key"), "{err:#}");
    }

    #[test]
    fn known_keys_cover_every_parsed_key() {
        // Self-check on the schema list: every key the parser consults is
        // declared, and the declared list has no duplicates.
        let mut seen = std::collections::BTreeSet::new();
        for k in KNOWN_KEYS {
            assert!(seen.insert(*k), "duplicate key {k}");
        }
        for k in ["serving.batch_deadline_ms", "admission.queue_depth", "adaptive.cooldown"] {
            assert!(KNOWN_KEYS.contains(&k), "{k} missing from KNOWN_KEYS");
        }
    }
}
