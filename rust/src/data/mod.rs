//! Test-set loading from the exported artifacts (`artifacts/data/*.bin`)
//! and golden cross-language vectors (`artifacts/golden/*.bin`).

use anyhow::{bail, Result};

use crate::runtime::artifacts::{read_tensor_f32, read_tensor_i32, GoldenEntry, Manifest};
use crate::tensor::Tensor;

/// An in-memory test split.
pub struct TestSet {
    /// `(count, H, W, C)`.
    pub images: Tensor,
    pub labels: Vec<i32>,
    pub name: String,
    pub num_classes: usize,
}

impl TestSet {
    /// Load a dataset's exported test split via the manifest.
    pub fn load(manifest: &Manifest, name: &str) -> Result<TestSet> {
        let entry = manifest.dataset(name)?;
        let images = read_tensor_f32(manifest.abspath(&entry.images))?;
        let (lshape, labels) = read_tensor_i32(manifest.abspath(&entry.labels))?;
        if images.shape()
            != [entry.count, entry.height, entry.width, entry.channels]
        {
            bail!("{name}: image tensor shape {:?} disagrees with manifest", images.shape());
        }
        if lshape != [entry.count] {
            bail!("{name}: label tensor shape {lshape:?} disagrees with manifest");
        }
        Ok(TestSet {
            images,
            labels,
            name: name.to_string(),
            num_classes: entry.num_classes,
        })
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Payload size per sample (H·W·C).
    pub fn payload(&self) -> usize {
        self.images.shape()[1..].iter().product()
    }

    /// The i-th image as a flat payload slice.
    pub fn image(&self, i: usize) -> &[f32] {
        let d = self.payload();
        &self.images.data()[i * d..(i + 1) * d]
    }
}

/// One loaded golden vector set (cross-checks rust coding vs python).
pub struct Golden {
    pub k: usize,
    pub s: usize,
    pub e: usize,
    /// `(N+1, K)` python encode matrix.
    pub enc_w: Tensor,
    /// `(K, D)` queries.
    pub queries: Tensor,
    /// `(N+1, D)` python-encoded payloads.
    pub coded: Tensor,
    /// Available worker indices used by the python decode.
    pub avail: Vec<usize>,
    /// `(K, |F|)` python decode matrix.
    pub decmat: Tensor,
    /// `(K, D)` python-decoded payloads.
    pub decoded: Tensor,
}

impl Golden {
    pub fn load(manifest: &Manifest, entry: &GoldenEntry) -> Result<Golden> {
        let g = |stem: &str| manifest.abspath(&format!("golden/{stem}_{}.bin", entry.tag));
        let (ashape, avail_raw) = read_tensor_i32(g("avail"))?;
        if ashape.len() != 1 {
            bail!("golden avail must be 1-D");
        }
        Ok(Golden {
            k: entry.k,
            s: entry.s,
            e: entry.e,
            enc_w: read_tensor_f32(g("enc_w"))?,
            queries: read_tensor_f32(g("queries"))?,
            coded: read_tensor_f32(g("coded"))?,
            avail: avail_raw.iter().map(|&x| x as usize).collect(),
            decmat: read_tensor_f32(g("decmat"))?,
            decoded: read_tensor_f32(g("decoded"))?,
        })
    }
}

#[cfg(test)]
mod tests {
    // TestSet/Golden loading against real artifacts is exercised by the
    // integration tests (rust/tests/artifacts_runtime.rs), which skip when
    // `make artifacts` has not run. The binary container parsing itself is
    // covered in runtime::artifacts.
}
