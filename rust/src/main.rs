//! ApproxIFER launcher.
//!
//! ```text
//! approxifer serve    [--config path] [--set k=v]...      # TCP serving front
//! approxifer infer    [--config path] [--set k=v]... [--samples N]
//!                                                         # offline smoke inference
//! approxifer figures  [--only figN] [--samples N] [--out DIR] [--seed S]
//!                                                         # regenerate paper figures
//! approxifer latency  [--groups N] [--out DIR]            # latency experiment
//! approxifer overload [--trace SPEC] [--admission POLICY] [--requests N]
//!                     [--queue-depth N]                   # open-loop overload run
//! approxifer golden                                        # cross-language goldens check
//! approxifer info                                          # artifact inventory
//! approxifer worker   [--connect ADDR] [--slot N] [--engine SPEC]
//!                     [--behavior PROG]                    # standalone fleet worker
//! ```

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use approxifer::cli::{Args, Spec};
use approxifer::coding::ServingScheme;
use approxifer::config::AppConfig;
use approxifer::coordinator::{Service, VerifyPolicy};
use approxifer::data::{Golden, TestSet};
use approxifer::harness::{self, FigureContext, Report};
use approxifer::runtime::{CompiledModel, Manifest, Runtime};
use approxifer::server::Server;
use approxifer::sim::faults::FaultProfile;
use approxifer::util::logging;
use approxifer::workers::PjrtEngine;

const USAGE: &str = "usage: approxifer <serve|infer|figures|latency|overload|golden|info|worker> [flags]
  common: --config FILE  --set section.key=value (repeatable)  --artifacts DIR
          --faults PROFILE (e.g. honest, crash:2@8, slow:1:0:40:0.5,
          flaky:1:0.2, byz-random:2:10, byz-collude:2:15, churn:3)
          --adaptive (live (S,E) re-tuning; tune via --set adaptive.window=N
          --set adaptive.target_miss_rate=R; SLO hedging via --set
          serving.slo_ms=MS)
  figures: --only ID  --samples N  --out DIR  --seed S
  latency: --groups N  --out DIR
  overload: --trace SPEC (poisson[:RATE] | diurnal[:LOW:HIGH:PERIOD_S] |
            bursty[:RATE:ON_MS:OFF_MS] | flash-crowd[:BASE:SPIKE:AT_MS:SPIKE_MS])
            --admission POLICY (reject | shed:batch)  --requests N
            --queue-depth N  --seed S
  infer:   --samples N
  worker:  --connect ADDR (coordinator fleet address)  --slot N
           --engine SPEC (mock:<payload>:<classes>[:<delay_ms>]; repeat the
           flag in a multi-tenant fleet — tenant t's model is the t-th spec)
           --behavior PROG (honest | crash@R | slow:B:T:P | flaky:P |
           byz-random:SIGMA | byz-signflip | byz-target:CLASS:BOOST |
           byz-collude:PACT:SCALE)  --seed S  --heartbeat-ms MS
           --reconnect-max N  --mute-after-ms MS (test hook)";

fn main() {
    logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        eprintln!("{USAGE}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let spec = Spec::new(&[
        ("config", true),
        ("set", true),
        ("artifacts", true),
        ("faults", true),
        ("adaptive", false),
        ("only", true),
        ("samples", true),
        ("out", true),
        ("seed", true),
        ("groups", true),
        ("trace", true),
        ("admission", true),
        ("requests", true),
        ("queue-depth", true),
        ("connect", true),
        ("slot", true),
        ("engine", true),
        ("behavior", true),
        ("heartbeat-ms", true),
        ("reconnect-max", true),
        ("mute-after-ms", true),
        ("help", false),
    ]);
    let args = Args::parse(argv, &spec).map_err(|e| anyhow::anyhow!("{e}"))?;
    if args.has("help") || args.subcommand.is_none() {
        println!("{USAGE}");
        return Ok(());
    }
    let overrides = args.get_all("set");
    let mut cfg = AppConfig::load(args.get("config"), &overrides)?;
    if let Some(a) = args.get("artifacts") {
        cfg.artifacts = a.to_string();
    }
    if let Some(f) = args.get("faults") {
        // Only the online-service subcommands execute behavior programs;
        // refuse silently ignoring the flag elsewhere (the figure/latency
        // harnesses drive their own per-group fault plans).
        match args.subcommand.as_deref() {
            Some("serve") | Some("infer") => cfg.fault_profile = Some(f.to_string()),
            other => bail!(
                "--faults applies to serve/infer only (got {})",
                other.unwrap_or("none")
            ),
        }
    }
    for flag in ["trace", "admission", "requests", "queue-depth"] {
        // The overload generator owns these; refuse silently ignoring them
        // on the other subcommands (same policy as --faults).
        if args.get(flag).is_some() && args.subcommand.as_deref() != Some("overload") {
            bail!(
                "--{flag} applies to overload only (got {})",
                args.subcommand.as_deref().unwrap_or("none")
            );
        }
    }
    for flag in
        ["connect", "slot", "engine", "behavior", "heartbeat-ms", "reconnect-max", "mute-after-ms"]
    {
        // Same policy for the worker process's own knobs.
        if args.get(flag).is_some() && args.subcommand.as_deref() != Some("worker") {
            bail!(
                "--{flag} applies to worker only (got {})",
                args.subcommand.as_deref().unwrap_or("none")
            );
        }
    }
    if args.has("adaptive") {
        // Same scope as --faults: only the online service has a control
        // plane to switch on.
        match args.subcommand.as_deref() {
            Some("serve") | Some("infer") => {
                if cfg.adaptive.is_none() {
                    cfg.adaptive = Some(Default::default());
                }
            }
            other => bail!(
                "--adaptive applies to serve/infer only (got {})",
                other.unwrap_or("none")
            ),
        }
    }
    match args.subcommand.as_deref().unwrap() {
        "serve" => serve(&cfg),
        "infer" => infer(&cfg, args.get_usize("samples", 64)?),
        "figures" => {
            let samples = args.get_usize("samples", 512)?;
            let seed = args.get_u64("seed", 20220807)?;
            let mut ctx = FigureContext::new(&cfg.artifacts, samples, seed)?;
            let mut rep = Report::new(args.get("out"));
            harness::figures::run(&mut ctx, &mut rep, args.get("only"))
        }
        "latency" => {
            let groups = args.get_usize("groups", 200)?;
            let mut rep = Report::new(args.get("out"));
            harness::latency::run(&mut rep, groups, args.get_u64("seed", 7)?)
        }
        "overload" => harness::overload::run(
            cfg.strategy,
            args.get("trace").unwrap_or("poisson"),
            args.get("admission"),
            args.get_usize("requests", 2000)?,
            args.get_usize("queue-depth", 256)?,
            args.get_u64("seed", 7)?,
        ),
        "golden" => golden(&cfg),
        "info" => info(&cfg),
        "worker" => worker(&args, cfg.seed),
        other => bail!("unknown subcommand '{other}'"),
    }
}

/// Run one standalone fleet worker process: dial the coordinator's fleet
/// listener, claim a slot, and serve `OP_TASK` frames through a local
/// engine — with the configured fault program executing worker-side.
fn worker(args: &approxifer::cli::Args, config_seed: u64) -> Result<()> {
    use approxifer::server::worker::{parse_engine_spec, run_worker, WorkerOptions};
    use approxifer::sim::faults::Behavior;
    use std::time::Duration;

    // One engine per tenant, in flag order; a single-tenant fleet passes
    // one (or none, for the default mock).
    let specs = args.get_all("engine");
    let engines = if specs.is_empty() {
        vec![parse_engine_spec("mock:8:10")?]
    } else {
        specs.iter().map(|s| parse_engine_spec(s)).collect::<Result<Vec<_>>>()?
    };
    let mut opts = WorkerOptions::default();
    if let Some(c) = args.get("connect") {
        opts.connect = c.to_string();
    }
    opts.slot = args.get_usize("slot", 0)?;
    if let Some(b) = args.get("behavior") {
        opts.behavior = Behavior::parse(b).map_err(|e| anyhow::anyhow!("--behavior: {e}"))?;
    }
    // The in-process pool salts the configured seed before deriving
    // per-worker streams; mirror it so `--seed S --slot i` replays exactly
    // the behavior that in-process worker i would have run under seed S.
    opts.seed = args.get_u64("seed", config_seed)? ^ 0x77;
    let hb = args.get_u64("heartbeat-ms", opts.heartbeat.as_millis() as u64)?;
    if hb == 0 {
        bail!("--heartbeat-ms must be >= 1");
    }
    opts.heartbeat = Duration::from_millis(hb);
    opts.max_reconnects = args.get_u64("reconnect-max", opts.max_reconnects as u64)? as u32;
    if args.get("mute-after-ms").is_some() {
        opts.mute_after = Some(Duration::from_millis(args.get_u64("mute-after-ms", 0)?));
    }
    log::info!(
        "worker starting: connect={} slot={} engines={} behavior={:?}",
        opts.connect,
        opts.slot,
        engines.len(),
        opts.behavior
    );
    run_worker(engines, opts)
}

/// Build the online service over the configured PJRT model: any strategy
/// (approxifer / replication / parm / uncoded) serves through the one
/// scheme-agnostic engine. With `fleet.enabled` the engine lives in the
/// worker processes instead: bind the fleet listener and wait for
/// `approxifer worker` joins.
fn build_service(cfg: &AppConfig) -> Result<(Arc<Service>, usize)> {
    use approxifer::workers::RemoteFleet;

    let manifest = Manifest::load(&cfg.artifacts)?;
    let entry = manifest.model(&cfg.arch, &cfg.dataset, 1)?;
    // Payload size comes straight from the manifest: only the in-process
    // path compiles the model (remote fleet workers own their engines).
    let payload: usize = entry.input[1..].iter().product();
    let scheme = cfg.strategy.scheme_tuned(cfg.params, cfg.nercc);
    let mut builder = Service::builder(scheme.clone())
        .batch_deadline(cfg.batch_deadline)
        .verify(if cfg.verify_decode {
            VerifyPolicy::on(cfg.verify_tol)
        } else {
            VerifyPolicy::off()
        })
        .seed(cfg.seed)
        .max_inflight(cfg.max_inflight)
        .decode_threads(cfg.decode_threads)
        .group_timeout(cfg.group_timeout);
    if let Some(slo) = cfg.slo {
        builder = builder.slo(slo);
    }
    if let Some(admission) = cfg.admission {
        builder = builder.admission(admission);
        log::info!(
            "admission control on: queue_depth={} shed_policy={:?} priority={:?}",
            admission.queue_depth,
            admission.shed_policy,
            admission.default_priority
        );
    }
    if let Some(adaptive) = cfg.adaptive {
        builder = builder.adaptive(adaptive);
        log::info!(
            "adaptive control plane on: window={} target_miss_rate={} cooldown={}",
            adaptive.window,
            adaptive.target_miss_rate,
            adaptive.cooldown
        );
    }
    if let Some(health) = &cfg.health {
        builder = builder.health(health.clone());
        log::info!(
            "worker health plane on: quarantine_threshold={} decay={} probation_ms={} \
             probation_passes={}",
            health.quarantine_threshold,
            health.decay,
            health.probation_ms,
            health.probation_passes
        );
    }
    let mut fleet_handle = None;
    match &cfg.fleet {
        Some(fc) => {
            // The coordinator can't reach into a worker process: fault
            // programs and latency models run inside the worker binary
            // (`worker --behavior`, `--engine mock:D:C:DELAY`).
            if cfg.fault_profile.is_some() {
                bail!(
                    "--faults/faults.profile with fleet.enabled: run the fault program \
                     inside the worker binary (approxifer worker --behavior PROG)"
                );
            }
            if cfg.worker_latency != approxifer::workers::LatencyModel::None {
                bail!(
                    "workers.latency models in-process workers; with fleet.enabled a \
                     worker's latency is real (use --engine mock:D:C:DELAY_MS on the \
                     worker for a synthetic one)"
                );
            }
            let need = scheme.num_workers();
            let slots = fc.workers.unwrap_or(need).max(need);
            let fleet = RemoteFleet::bind(fc, slots)?;
            println!(
                "fleet listening on {} ({slots} slots, scheme needs {need}); join with: \
                 approxifer worker --connect {} --slot <i> --engine mock:{payload}:{}",
                fleet.addr(),
                fleet.addr(),
                entry.num_classes
            );
            fleet_handle = Some(fleet.handle());
            builder = builder.fleet(Box::new(fleet));
        }
        None => {
            let rt = Runtime::cpu()?;
            let model = CompiledModel::load(&rt, &manifest.root, entry)?;
            builder = builder
                .engine(Arc::new(PjrtEngine::new(model)))
                .worker_latency(cfg.worker_latency);
            if let Some(spec) = &cfg.fault_profile {
                let profile = FaultProfile::parse(spec, scheme.num_workers(), cfg.seed)
                    .map_err(|e| anyhow::anyhow!("--faults: {e}"))?;
                log::info!(
                    "fault profile '{}': faulty workers {:?}",
                    profile.name,
                    profile.faulty()
                );
                builder = builder.fault_profile(profile);
            }
        }
    }
    let service = Arc::new(builder.spawn()?);
    if let Some(handle) = fleet_handle {
        // Don't serve errors into the first groups just because the
        // workers are still starting; but don't block forever either —
        // joins are accepted for the life of the service.
        let need = scheme.num_workers();
        if !handle.wait_for_workers(need, std::time::Duration::from_secs(10)) {
            log::warn!(
                "only {}/{need} fleet workers joined after 10s; groups will lean on the \
                 code's straggler budget until the rest join",
                handle.live_workers()
            );
        }
    }
    Ok((service, payload))
}

fn serve(cfg: &AppConfig) -> Result<()> {
    if let Some(tc) = &cfg.tenants {
        return serve_tenants(cfg, tc);
    }
    let (service, payload) = build_service(cfg)?;
    let server = Server::start(&cfg.bind, service.clone(), payload)?;
    // Report the scheme's actual envelope, not the raw config triple (the
    // baselines interpret (K,S,E) their own way).
    let scheme = service.scheme();
    println!(
        "approxifer serving {}/{} scheme={} K={} tolerates S={} E={} ({} workers) on {}",
        cfg.arch,
        cfg.dataset,
        scheme.name(),
        scheme.group_size(),
        scheme.stragglers_tolerated(),
        scheme.byzantine_tolerated(),
        scheme.num_workers(),
        server.addr()
    );
    // Serve until killed; dump metrics every 30s.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(30));
        println!("{}", service.metrics.report());
    }
}

/// Multi-tenant serving: one shared fleet (in-process pool or remote),
/// one service pipeline per `tenants.<name>` table, one fairness scheduler
/// at the dispatch boundary. Tenant models come from their engine specs —
/// every worker hosts the whole engine table, indexed by the tenant tag
/// in each task's group id.
fn serve_tenants(cfg: &AppConfig, tc: &approxifer::config::TenantsConfig) -> Result<()> {
    use approxifer::coordinator::TenantRegistry;
    use approxifer::server::worker::parse_engine_spec;
    use approxifer::workers::{RemoteFleet, WorkerFleet, WorkerPool, WorkerSpec};

    if cfg.fault_profile.is_some() {
        bail!(
            "--faults/faults.profile with tenants.enabled: which tenant would it hit? \
             Fault programs run worker-side (fleet workers: --behavior) or through the \
             test/bench harness hooks"
        );
    }
    // Tenant i's model is engine-table slot i on every worker.
    let engines = tc
        .specs
        .iter()
        .map(|s| {
            parse_engine_spec(&s.engine)
                .with_context(|| format!("tenant '{}' engine spec", s.name))
        })
        .collect::<Result<Vec<_>>>()?;
    let payloads: Vec<usize> = engines.iter().map(|e| e.payload()).collect();
    let need =
        tc.specs.iter().map(|s| s.strategy.num_workers(s.params)).max().unwrap_or(1);

    let mut fleet_handle = None;
    let fleet: Box<dyn WorkerFleet> = match &cfg.fleet {
        Some(fc) => {
            if cfg.worker_latency != approxifer::workers::LatencyModel::None {
                bail!(
                    "workers.latency models in-process workers; with fleet.enabled a \
                     worker's latency is real"
                );
            }
            let slots = fc.workers.unwrap_or(need).max(need);
            let fleet = RemoteFleet::bind(fc, slots)?;
            let engine_flags: Vec<String> =
                tc.specs.iter().map(|s| format!("--engine {}", s.engine)).collect();
            println!(
                "fleet listening on {} ({slots} slots, largest tenant needs {need}); join \
                 with: approxifer worker --connect {} --slot <i> {}",
                fleet.addr(),
                fleet.addr(),
                engine_flags.join(" ")
            );
            fleet_handle = Some(fleet.handle());
            Box::new(fleet)
        }
        None => Box::new(WorkerPool::spawn_multi(
            engines,
            &vec![WorkerSpec::new(cfg.worker_latency); need],
            cfg.seed,
            None,
        )),
    };
    // Tenant specs inherit the global health.* table at config load; the
    // registry builds the one shared plane over the physical fleet from it.
    if cfg.health.is_some() {
        log::info!("worker health plane on (shared across all tenants)");
    }
    let registry = TenantRegistry::spawn(fleet, tc.specs.clone(), tc.capacity)?;
    if let Some(handle) = fleet_handle {
        if !handle.wait_for_workers(need, std::time::Duration::from_secs(10)) {
            log::warn!(
                "only {}/{need} fleet workers joined after 10s; groups will lean on the \
                 codes' straggler budgets until the rest join",
                handle.live_workers()
            );
        }
    }
    let server = Server::start_tenants(
        &cfg.bind,
        registry
            .tenants()
            .iter()
            .zip(&payloads)
            .map(|(t, &p)| (t.service.clone(), p))
            .collect(),
    )?;
    for (i, t) in registry.tenants().iter().enumerate() {
        let scheme = t.service.scheme();
        println!(
            "tenant {i} '{}': scheme={} K={} tolerates S={} E={} weight={} budget={} \
             payload={}",
            t.spec.name,
            scheme.name(),
            scheme.group_size(),
            scheme.stragglers_tolerated(),
            scheme.byzantine_tolerated(),
            t.spec.weight,
            t.spec.budget,
            payloads[i]
        );
    }
    println!(
        "approxifer serving {} tenants (fair capacity {}) on {}",
        registry.tenants().len(),
        tc.capacity,
        server.addr()
    );
    // Serve until killed; dump per-tenant metrics every 30s.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(30));
        for t in registry.tenants() {
            println!("[tenant {}]\n{}", t.spec.name, t.service.metrics.report());
        }
    }
}

fn infer(cfg: &AppConfig, samples: usize) -> Result<()> {
    let manifest = Manifest::load(&cfg.artifacts)?;
    let testset = TestSet::load(&manifest, &cfg.dataset)?;
    let (service, _payload) = build_service(cfg)?;
    let n = samples.min(testset.len());
    let t0 = std::time::Instant::now();
    let handles: Vec<_> =
        (0..n).map(|i| service.submit(testset.image(i).to_vec())).collect();
    let mut correct = 0usize;
    for (i, h) in handles.into_iter().enumerate() {
        let pred = h.wait()?;
        let arg = pred
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap();
        if arg as i32 == testset.labels[i] {
            correct += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {n} queries in {wall:.2}s ({:.1} q/s): coded accuracy {:.2}% (base {:.2}%)",
        n as f64 / wall,
        100.0 * correct as f64 / n as f64,
        100.0 * manifest.model(&cfg.arch, &cfg.dataset, 1)?.base_test_acc,
    );
    println!("{}", service.metrics.report());
    Ok(())
}

/// Verify the rust coding implementation bit-near against the python-exported
/// golden vectors (encode matrix, coded payloads, decode matrix, decodes).
fn golden(cfg: &AppConfig) -> Result<()> {
    use approxifer::coding::{ApproxIferCode, CodeParams};
    let manifest = Manifest::load(&cfg.artifacts)?;
    anyhow::ensure!(!manifest.golden.is_empty(), "no golden entries in manifest");
    for entry in &manifest.golden {
        let g = Golden::load(&manifest, entry)
            .with_context(|| format!("loading golden {}", entry.tag))?;
        let code = ApproxIferCode::new(CodeParams::new(g.k, g.s, g.e));
        // Encode matrix must match python's.
        let w = code.encode_matrix();
        anyhow::ensure!(w.len() == g.enc_w.len(), "{}: W size", entry.tag);
        for (a, b) in w.iter().zip(g.enc_w.data()) {
            anyhow::ensure!((a - b).abs() <= 1e-5, "{}: W entry {a} vs {b}", entry.tag);
        }
        // Encoding the golden queries must match.
        let k = g.k;
        let d = g.queries.shape()[1];
        // The production flat-buffer path: stage the golden queries as one
        // block and GEMM-encode, exactly as the serving batcher does.
        let queries = approxifer::coding::GroupBlock::from_vec(g.queries.data().to_vec(), k, d);
        let mut staged = approxifer::coding::BlockBuf::unpooled(code.params().num_workers(), d);
        code.encode_block(&queries, &mut staged);
        let coded = staged.freeze();
        for i in 0..code.params().num_workers() {
            for (t, (a, b)) in
                coded.row(i).iter().zip(&g.coded.data()[i * d..(i + 1) * d]).enumerate()
            {
                anyhow::ensure!(
                    (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                    "{}: coded[{i}][{t}] {a} vs {b}",
                    entry.tag
                );
            }
        }
        // Decoding python's coded payloads with python's availability set.
        let payloads: Vec<&[f32]> =
            g.avail.iter().map(|&i| &g.coded.data()[i * d..(i + 1) * d]).collect();
        let decoded = code.decode(&g.avail, &payloads);
        for j in 0..k {
            for t in 0..d {
                let a = decoded[j][t];
                let b = g.decoded.data()[j * d + t];
                anyhow::ensure!(
                    (a - b).abs() <= 1e-3 * (1.0 + b.abs()),
                    "{}: decoded[{j}][{t}] {a} vs {b}",
                    entry.tag
                );
            }
        }
        println!("golden {}: OK (K={} S={} E={})", entry.tag, g.k, g.s, g.e);
    }
    println!("all {} golden sets match", manifest.golden.len());
    Ok(())
}

fn info(cfg: &AppConfig) -> Result<()> {
    let manifest = Manifest::load(&cfg.artifacts)?;
    println!("artifacts at {:?}", manifest.root);
    println!("models:");
    for m in &manifest.models {
        println!(
            "  {}/{} b{} input={:?} params={} base_acc={:.4}",
            m.arch, m.dataset, m.batch, m.input, m.param_count, m.base_test_acc
        );
    }
    println!("datasets:");
    for d in &manifest.datasets {
        println!(
            "  {} {}x{}x{}x{} classes={}",
            d.name, d.count, d.height, d.width, d.channels, d.num_classes
        );
    }
    println!("encoders:");
    for e in &manifest.encoders {
        println!("  k={} s={} d={} -> {}", e.k, e.s, e.payload, e.path);
    }
    println!("golden sets: {}", manifest.golden.len());
    Ok(())
}
