//! Dense linear-algebra substrate (no external crates): row-major `Mat`,
//! Householder QR least-squares, and one-sided Jacobi SVD / homogeneous
//! solver. Sized and tuned for the decoder's error-locator systems
//! (tens of rows/columns, f64).

pub mod homogeneous;
pub mod mat;
pub mod qr;

pub use homogeneous::{cond2, min_norm_solution, svd_right, Svd};
pub use mat::{dot, norm2, Mat};
pub use qr::{lstsq, LinalgError, Qr};
