//! Householder QR factorization and least-squares solver.
//!
//! This is the numerical core behind the paper's error-locator
//! (Algorithm 1 / Algorithm 2): the system `P(β_i) = y_i·Q(β_i)` with
//! `Q`'s constant coefficient pinned to 1 becomes an overdetermined
//! *inhomogeneous* least-squares problem, solved here via Householder QR
//! (numerically stable for the moderately ill-conditioned Chebyshev
//! Vandermonde blocks the locator produces).

use super::mat::Mat;

/// Compact Householder QR of an `m×n` matrix with `m ≥ n`:
/// stores the reflectors in-place plus R's diagonal separately.
pub struct Qr {
    /// m×n: strict upper triangle = R (above diag), lower triangle +
    /// `diag` slot = Householder vectors.
    qr: Mat,
    /// R's diagonal.
    rdiag: Vec<f64>,
}

/// Errors from the linear-algebra layer.
#[derive(Debug)]
pub enum LinalgError {
    RankDeficient { col: usize, value: f64, tol: f64 },
    Dims(String),
    NoConverge(String),
}

impl std::fmt::Display for LinalgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LinalgError::RankDeficient { col, value, tol } => write!(
                f,
                "matrix is rank-deficient (|r[{col}][{col}]| = {value:.3e} below tol {tol:.3e})"
            ),
            LinalgError::Dims(msg) => write!(f, "dimension mismatch: {msg}"),
            LinalgError::NoConverge(msg) => write!(f, "iteration failed to converge: {msg}"),
        }
    }
}

impl std::error::Error for LinalgError {}

impl Qr {
    /// Factor `a` (m×n, m ≥ n).
    pub fn factor(a: &Mat) -> Result<Qr, LinalgError> {
        let (m, n) = (a.rows(), a.cols());
        if m < n {
            return Err(LinalgError::Dims(format!("QR needs m>=n, got {m}x{n}")));
        }
        let mut qr = a.clone();
        let mut rdiag = vec![0.0; n];
        for k in 0..n {
            // Norm of column k below the diagonal.
            let mut nrm = 0.0;
            for i in k..m {
                nrm = hypot(nrm, qr[(i, k)]);
            }
            if nrm == 0.0 {
                rdiag[k] = 0.0;
                continue;
            }
            let mut nrm = nrm;
            if qr[(k, k)] < 0.0 {
                nrm = -nrm;
            }
            for i in k..m {
                qr[(i, k)] /= nrm;
            }
            qr[(k, k)] += 1.0;
            // Apply the reflector to the remaining columns.
            for j in (k + 1)..n {
                let mut s = 0.0;
                for i in k..m {
                    s += qr[(i, k)] * qr[(i, j)];
                }
                s = -s / qr[(k, k)];
                for i in k..m {
                    let vik = qr[(i, k)];
                    qr[(i, j)] += s * vik;
                }
            }
            rdiag[k] = -nrm;
        }
        Ok(Qr { qr, rdiag })
    }

    /// Minimum of |R_kk| over the diagonal — a cheap rank/conditioning probe.
    pub fn min_rdiag(&self) -> f64 {
        self.rdiag.iter().fold(f64::INFINITY, |m, x| m.min(x.abs()))
    }

    /// Solve least squares `min ‖A·x − b‖₂`. Errors if R is numerically
    /// singular (relative tolerance on R's diagonal).
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>, LinalgError> {
        let (m, n) = (self.qr.rows(), self.qr.cols());
        if b.len() != m {
            return Err(LinalgError::Dims(format!("rhs length {} != rows {m}", b.len())));
        }
        let max_r = self.rdiag.iter().fold(0.0f64, |mx, x| mx.max(x.abs()));
        let tol = max_r * 1e-13;
        let mut y = b.to_vec();
        // Apply Qᵀ.
        for k in 0..n {
            if self.qr[(k, k)] == 0.0 {
                continue;
            }
            let mut s = 0.0;
            for i in k..m {
                s += self.qr[(i, k)] * y[i];
            }
            s = -s / self.qr[(k, k)];
            for i in k..m {
                y[i] += s * self.qr[(i, k)];
            }
        }
        // Back-substitute R x = y[..n].
        let mut x = vec![0.0; n];
        for k in (0..n).rev() {
            let r = self.rdiag[k];
            if r.abs() <= tol {
                return Err(LinalgError::RankDeficient { col: k, value: r.abs(), tol });
            }
            let mut s = y[k];
            for j in (k + 1)..n {
                s -= self.qr[(k, j)] * x[j];
            }
            x[k] = s / r;
        }
        Ok(x)
    }
}

/// One-shot least squares: `argmin_x ‖A·x − b‖₂` via Householder QR.
pub fn lstsq(a: &Mat, b: &[f64]) -> Result<Vec<f64>, LinalgError> {
    Qr::factor(a)?.solve(b)
}

/// Robust hypot (avoids overflow for the column norms).
fn hypot(a: f64, b: f64) -> f64 {
    let (a, b) = (a.abs(), b.abs());
    let (hi, lo) = if a > b { (a, b) } else { (b, a) };
    if hi == 0.0 {
        0.0
    } else {
        let r = lo / hi;
        hi * (1.0 + r * r).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::norm2;
    use crate::testing::{assert_allclose, forall};

    #[test]
    fn solves_square_system_exactly() {
        // x + 2y = 5 ; 3x + 4y = 11 → x=1, y=2
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let x = lstsq(&a, &[5.0, 11.0]).unwrap();
        assert_allclose(&x, &[1.0, 2.0], 1e-12);
    }

    #[test]
    fn least_squares_residual_orthogonal_to_columns() {
        forall("lstsq-orthogonal-residual", 50, |g| {
            let m = g.usize_in(3, 12);
            let n = g.usize_in(1, m.min(6));
            let a = Mat::from_fn(m, n, |_, _| g.f64_in(-5.0, 5.0));
            let b = g.vec_f64(m, -5.0, 5.0);
            let x = match lstsq(&a, &b) {
                Ok(x) => x,
                Err(LinalgError::RankDeficient { .. }) => return, // fine for random A
                Err(e) => panic!("{e}"),
            };
            let ax = a.matvec(&x);
            let r: Vec<f64> = ax.iter().zip(&b).map(|(p, q)| p - q).collect();
            // Residual must be orthogonal to every column of A.
            let at = a.t();
            for j in 0..n {
                let d: f64 = at.row(j).iter().zip(&r).map(|(c, rr)| c * rr).sum();
                let scale = norm2(at.row(j)) * norm2(&r) + 1.0;
                assert!(d.abs() / scale < 1e-9, "col {j}: dot {d}");
            }
        });
    }

    #[test]
    fn recovers_exact_solution_for_consistent_overdetermined() {
        forall("lstsq-consistent", 50, |g| {
            let m = g.usize_in(4, 14);
            let n = g.usize_in(1, 4);
            let a = Mat::from_fn(m, n, |_, _| g.f64_in(-3.0, 3.0));
            let xtrue = g.vec_f64(n, -3.0, 3.0);
            let b = a.matvec(&xtrue);
            match lstsq(&a, &b) {
                Ok(x) => assert_allclose(&x, &xtrue, 1e-8),
                Err(LinalgError::RankDeficient { .. }) => {}
                Err(e) => panic!("{e}"),
            }
        });
    }

    #[test]
    fn rank_deficient_is_detected() {
        // Second column is 2× the first.
        let a = Mat::from_rows(3, 2, &[1.0, 2.0, 2.0, 4.0, 3.0, 6.0]);
        assert!(matches!(
            lstsq(&a, &[1.0, 2.0, 3.0]),
            Err(LinalgError::RankDeficient { .. })
        ));
    }

    #[test]
    fn wrong_rhs_length_is_dims_error() {
        let a = Mat::eye(3);
        assert!(matches!(lstsq(&a, &[1.0, 2.0]), Err(LinalgError::Dims(_))));
    }

    #[test]
    fn underdetermined_rejected() {
        let a = Mat::zeros(2, 3);
        assert!(matches!(Qr::factor(&a), Err(LinalgError::Dims(_))));
    }

    #[test]
    fn hypot_no_overflow() {
        let h = hypot(1e200, 1e200);
        assert!(h.is_finite());
        assert!((h - 1e200 * std::f64::consts::SQRT_2).abs() / h < 1e-12);
    }
}
