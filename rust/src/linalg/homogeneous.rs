//! Homogeneous least-squares: `argmin_{‖x‖=1} ‖A·x‖₂` — the smallest right
//! singular vector of `A`.
//!
//! This is the *pure* form of the paper's Algorithm 1 (Step 1 finds a
//! non-trivial solution of the homogeneous system `P(β_i) − y_i Q(β_i) = 0`).
//! The production locator uses the pinned-`Q₀=1` inhomogeneous variant
//! (paper's Algorithm 2) solved with QR; this module provides the homogeneous
//! variant both as a fallback when the pinned system is singular and as the
//! ablation comparator (`bench_locator --ablation`).
//!
//! Method: one-sided Jacobi SVD on `A` (orthogonalize column pairs of a
//! working copy with Givens-like rotations until convergence); the right
//! singular vectors accumulate in `V`, and the smallest singular value's
//! column of `V` is the answer. Matrices here are at most ~60×30, so the
//! O(n³)·sweeps cost is negligible and robustness is what matters.

use super::mat::{norm2, Mat};
use super::qr::LinalgError;

/// Full set of singular values (descending) and right singular vectors.
pub struct Svd {
    /// Singular values, descending.
    pub sigma: Vec<f64>,
    /// n×n: column j is the right singular vector for `sigma[j]`.
    pub v: Mat,
}

/// One-sided Jacobi SVD (values + right vectors only). `a` is m×n with m ≥ n.
pub fn svd_right(a: &Mat) -> Result<Svd, LinalgError> {
    let (m, n) = (a.rows(), a.cols());
    if m < n {
        return Err(LinalgError::Dims(format!("svd_right needs m>=n, got {m}x{n}")));
    }
    // Work on columns of U = A (m×n), accumulate V (n×n).
    let mut u = a.clone();
    let mut v = Mat::eye(n);
    let eps = 1e-15;
    let max_sweeps = 60;
    let mut converged = false;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Gram entries for the (p,q) column pair.
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..m {
                    let (x, y) = (u[(i, p)], u[(i, q)]);
                    app += x * x;
                    aqq += y * y;
                    apq += x * y;
                }
                let denom = (app * aqq).sqrt();
                if denom > 0.0 {
                    off = off.max(apq.abs() / denom);
                }
                if apq.abs() <= eps * denom {
                    continue;
                }
                // Jacobi rotation that zeroes the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let (x, y) = (u[(i, p)], u[(i, q)]);
                    u[(i, p)] = c * x - s * y;
                    u[(i, q)] = s * x + c * y;
                }
                for i in 0..n {
                    let (x, y) = (v[(i, p)], v[(i, q)]);
                    v[(i, p)] = c * x - s * y;
                    v[(i, q)] = s * x + c * y;
                }
            }
        }
        if off < 1e-14 {
            converged = true;
            break;
        }
    }
    if !converged {
        // Jacobi always makes progress; for our tiny matrices this is
        // effectively unreachable, but surface it rather than silently
        // returning garbage.
        return Err(LinalgError::NoConverge("jacobi svd exceeded sweep limit".into()));
    }
    // Singular values are the column norms of the rotated U.
    let mut order: Vec<usize> = (0..n).collect();
    let sig: Vec<f64> = (0..n)
        .map(|j| {
            let col: Vec<f64> = (0..m).map(|i| u[(i, j)]).collect();
            norm2(&col)
        })
        .collect();
    order.sort_by(|&a, &b| sig[b].partial_cmp(&sig[a]).unwrap());
    let sigma: Vec<f64> = order.iter().map(|&j| sig[j]).collect();
    let vperm = Mat::from_fn(n, n, |i, j| v[(i, order[j])]);
    Ok(Svd { sigma, v: vperm })
}

/// `argmin_{‖x‖=1} ‖A·x‖` — the right singular vector of the smallest
/// singular value.
pub fn min_norm_solution(a: &Mat) -> Result<Vec<f64>, LinalgError> {
    let svd = svd_right(a)?;
    let n = a.cols();
    let j = n - 1;
    Ok((0..n).map(|i| svd.v[(i, j)]).collect())
}

/// 2-norm condition number estimate σ_max/σ_min.
pub fn cond2(a: &Mat) -> Result<f64, LinalgError> {
    let svd = svd_right(a)?;
    let smin = *svd.sigma.last().unwrap();
    Ok(if smin == 0.0 { f64::INFINITY } else { svd.sigma[0] / smin })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_close, forall};

    #[test]
    fn svd_of_diagonal() {
        let a = Mat::from_rows(3, 3, &[3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0]);
        let s = svd_right(&a).unwrap();
        assert_close(s.sigma[0], 3.0, 1e-12);
        assert_close(s.sigma[1], 2.0, 1e-12);
        assert_close(s.sigma[2], 1.0, 1e-12);
    }

    #[test]
    fn min_norm_solution_annihilates_rank_deficient() {
        // Columns: c2 = 2*c1 → nullspace direction (2, -1)/√5.
        let a = Mat::from_rows(3, 2, &[1.0, 2.0, -1.0, -2.0, 0.5, 1.0]);
        let x = min_norm_solution(&a).unwrap();
        let ax = a.matvec(&x);
        assert!(norm2(&ax) < 1e-12, "Ax = {ax:?}");
        assert_close(norm2(&x), 1.0, 1e-12);
    }

    #[test]
    fn singular_values_match_gram_eigenvalues() {
        forall("svd-gram", 30, |g| {
            let m = g.usize_in(2, 10);
            let n = g.usize_in(1, m.min(6));
            let a = Mat::from_fn(m, n, |_, _| g.f64_in(-4.0, 4.0));
            let s = svd_right(&a).unwrap();
            // ‖A‖_F² = Σ σᵢ².
            let fro2: f64 = a.fro_norm().powi(2);
            let sig2: f64 = s.sigma.iter().map(|x| x * x).sum();
            assert_close(fro2, sig2, 1e-9);
            // Descending.
            for w in s.sigma.windows(2) {
                assert!(w[0] >= w[1] - 1e-12);
            }
        });
    }

    #[test]
    fn right_vectors_are_orthonormal() {
        forall("svd-v-orthonormal", 30, |g| {
            let m = g.usize_in(3, 10);
            let n = g.usize_in(1, m.min(5));
            let a = Mat::from_fn(m, n, |_, _| g.f64_in(-4.0, 4.0));
            let s = svd_right(&a).unwrap();
            let vtv = s.v.t().matmul(&s.v);
            for i in 0..n {
                for j in 0..n {
                    let expect = if i == j { 1.0 } else { 0.0 };
                    assert_close(vtv[(i, j)], expect, 1e-9);
                }
            }
        });
    }

    #[test]
    fn min_norm_residual_is_smallest_singular_value() {
        forall("svd-min-residual", 30, |g| {
            let m = g.usize_in(3, 10);
            let n = g.usize_in(2, m.min(5));
            let a = Mat::from_fn(m, n, |_, _| g.f64_in(-4.0, 4.0));
            let s = svd_right(&a).unwrap();
            let x = min_norm_solution(&a).unwrap();
            let res = norm2(&a.matvec(&x));
            assert_close(res, *s.sigma.last().unwrap(), 1e-8);
        });
    }

    #[test]
    fn cond2_of_identity_is_one() {
        assert_close(cond2(&Mat::eye(4)).unwrap(), 1.0, 1e-12);
    }
}
