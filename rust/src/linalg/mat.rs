//! Dense row-major `f64` matrix. This is the linear-algebra substrate for the
//! decoder's error-locator systems — small (tens of rows/columns), so clarity
//! and numerical robustness beat blocking/SIMD here. The f32 inference hot
//! path lives in `tensor`/`coding` instead.

use std::fmt;
use std::ops::{Index, IndexMut};

/// Dense row-major matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major slice.
    pub fn from_rows(rows: usize, cols: usize, data: &[f64]) -> Mat {
        assert_eq!(data.len(), rows * cols, "from_rows: data length mismatch");
        Mat { rows, cols, data: data.to_vec() }
    }

    /// Build with a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Matrix transpose.
    pub fn t(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// Matrix–matrix product.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul: inner dims {} vs {}", self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for j in 0..other.cols {
                    out_row[j] += a * orow[j];
                }
            }
        }
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len(), "matvec: dims {} vs {}", self.cols, x.len());
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max |a_ij|.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, x| m.max(x.abs()))
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:>10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

/// Euclidean norm of a vector.
pub fn norm2(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

/// Dot product.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_allclose, forall};

    #[test]
    fn eye_matmul_is_identity() {
        let a = Mat::from_rows(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let i2 = Mat::eye(2);
        let i3 = Mat::eye(3);
        assert_eq!(i2.matmul(&a), a);
        assert_eq!(a.matmul(&i3), a);
    }

    #[test]
    fn matmul_known_values() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_rows(2, 2, &[5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_involution() {
        forall("transpose-involution", 50, |g| {
            let r = g.usize_in(1, 8);
            let c = g.usize_in(1, 8);
            let m = Mat::from_fn(r, c, |_, _| g.f64_messy());
            assert_eq!(m.t().t(), m);
        });
    }

    #[test]
    fn matvec_matches_matmul() {
        forall("matvec-matmul", 50, |g| {
            let r = g.usize_in(1, 8);
            let c = g.usize_in(1, 8);
            let m = Mat::from_fn(r, c, |_, _| g.f64_in(-10.0, 10.0));
            let x = g.vec_f64(c, -10.0, 10.0);
            let xm = Mat::from_rows(c, 1, &x);
            let via_matmul = m.matmul(&xm);
            assert_allclose(&m.matvec(&x), via_matmul.data(), 1e-12);
        });
    }

    #[test]
    fn matmul_associative() {
        forall("matmul-assoc", 30, |g| {
            let (m, n) = (g.usize_in(1, 6), g.usize_in(1, 6));
            let (p, q) = (g.usize_in(1, 6), g.usize_in(1, 6));
            let a = Mat::from_fn(m, n, |_, _| g.f64_in(-2.0, 2.0));
            let b = Mat::from_fn(n, p, |_, _| g.f64_in(-2.0, 2.0));
            let c = Mat::from_fn(p, q, |_, _| g.f64_in(-2.0, 2.0));
            let left = a.matmul(&b).matmul(&c);
            let right = a.matmul(&b.matmul(&c));
            assert_allclose(left.data(), right.data(), 1e-10);
        });
    }

    #[test]
    fn norms() {
        assert_eq!(norm2(&[3.0, 4.0]), 5.0);
        let m = Mat::from_rows(1, 2, &[3.0, 4.0]);
        assert_eq!(m.fro_norm(), 5.0);
        assert_eq!(m.max_abs(), 4.0);
    }
}
