//! PJRT runtime facade: load AOT HLO-text artifacts and execute them from
//! the serving hot path.
//!
//! The real backend wraps the `xla` crate (PJRT C API, CPU client); that
//! binding is not available in this build environment, so this module ships
//! the same API surface with executable loading stubbed out: [`Runtime`]
//! construction succeeds (so artifact-free code paths — mocks, coding,
//! harness ablations — run unimpeded), and [`Runtime::load_hlo_text`]
//! returns a descriptive error. Everything above this layer programs
//! against [`CompiledModel`]/[`CompiledEncoder`] and is agnostic to which
//! backend is underneath; swapping the real PJRT client back in is local to
//! this file.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

use super::artifacts::ModelEntry;

/// Process-wide runtime handle (PJRT client in the real backend).
pub struct Runtime {
    platform: String,
}

impl Runtime {
    /// Create the CPU runtime handle.
    pub fn cpu() -> Result<Runtime> {
        log::debug!("runtime: PJRT backend unavailable, using stub (no HLO execution)");
        Ok(Runtime { platform: "cpu-stub".to_string() })
    }

    pub fn platform(&self) -> String {
        self.platform.clone()
    }

    /// Load + compile an HLO-text artifact. Always errors in the stub
    /// backend; callers surface this as "artifacts not executable here".
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        // Validate the artifact exists so the error distinguishes "no
        // artifacts built" from "backend missing".
        std::fs::metadata(path).with_context(|| format!("reading HLO artifact {path:?}"))?;
        bail!("PJRT backend not built: cannot compile {path:?} (xla bindings unavailable)")
    }
}

/// A compiled executable handle (opaque; not constructible in the stub).
pub struct Executable {
    _priv: (),
}

impl Executable {
    /// Execute with f32 inputs; returns the elements of the ROOT tuple.
    pub fn run(&self, _inputs: &[(&[usize], &[f32])]) -> Result<Vec<Tensor>> {
        bail!("PJRT backend not built: executable cannot run")
    }
}

/// A hosted model `f`, compiled for a fixed batch size.
pub struct CompiledModel {
    exe: Executable,
    /// `[batch, H, W, C]`.
    pub input: Vec<usize>,
    pub num_classes: usize,
    pub arch: String,
    pub dataset: String,
}

impl CompiledModel {
    /// Load from a manifest entry.
    pub fn load(rt: &Runtime, root: &Path, entry: &ModelEntry) -> Result<CompiledModel> {
        let exe = rt.load_hlo_text(root.join(&entry.path))?;
        Ok(CompiledModel {
            exe,
            input: entry.input.clone(),
            num_classes: entry.num_classes,
            arch: entry.arch.clone(),
            dataset: entry.dataset.clone(),
        })
    }

    pub fn batch(&self) -> usize {
        self.input[0]
    }

    /// Payload size per query (H·W·C).
    pub fn payload(&self) -> usize {
        self.input[1..].iter().product()
    }

    /// Run inference on a `(B, H, W, C)` batch; returns `(B, num_classes)`
    /// logits. The batch dimension must match the compiled batch exactly
    /// (pad with [`CompiledModel::infer_padded`] otherwise).
    pub fn infer(&self, x: &Tensor) -> Result<Tensor> {
        if x.shape() != self.input.as_slice() {
            bail!(
                "input shape {:?} != compiled shape {:?} ({}/{})",
                x.shape(),
                self.input,
                self.arch,
                self.dataset
            );
        }
        let mut out = self.exe.run(&[(&self.input, x.data())])?;
        if out.len() != 1 {
            bail!("expected 1 output, got {}", out.len());
        }
        Ok(out.remove(0))
    }

    /// Run inference on the first `n ≤ batch` rows of a padded batch: input
    /// has any leading count, it is zero-padded/truncated to the compiled
    /// batch, and only the first `n` logit rows are returned.
    pub fn infer_padded(&self, x: &Tensor, n: usize) -> Result<Tensor> {
        let b = self.batch();
        if n > b {
            bail!("n={n} exceeds compiled batch {b}");
        }
        let payload = self.payload();
        let mut buf = vec![0.0f32; b * payload];
        let take = n.min(x.shape()[0]) * payload;
        buf[..take].copy_from_slice(&x.data()[..take]);
        let padded = Tensor::from_vec(&self.input, buf);
        let logits = self.infer(&padded)?;
        let c = self.num_classes;
        Ok(Tensor::from_vec(&[n, c], logits.data()[..n * c].to_vec()))
    }
}

/// A compiled Pallas Berrut encoder: `(K, D) -> (N+1, D)`.
pub struct CompiledEncoder {
    exe: Executable,
    pub k: usize,
    pub workers: usize,
    pub payload: usize,
}

impl CompiledEncoder {
    pub fn load(
        rt: &Runtime,
        root: &Path,
        entry: &super::artifacts::EncoderEntry,
    ) -> Result<CompiledEncoder> {
        let exe = rt.load_hlo_text(root.join(&entry.path))?;
        let workers = if entry.e == 0 {
            entry.k + entry.s
        } else {
            2 * (entry.k + entry.e) + entry.s
        };
        Ok(CompiledEncoder { exe, k: entry.k, workers, payload: entry.payload })
    }

    /// Encode `(K, D)` flattened queries into `(N+1, D)` coded payloads.
    pub fn encode(&self, queries: &Tensor) -> Result<Tensor> {
        if queries.shape() != [self.k, self.payload] {
            bail!(
                "encoder input shape {:?} != [{}, {}]",
                queries.shape(),
                self.k,
                self.payload
            );
        }
        let mut out = self.exe.run(&[(&[self.k, self.payload], queries.data())])?;
        Ok(out.remove(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_constructs_and_reports_platform() {
        let rt = Runtime::cpu().unwrap();
        assert_eq!(rt.platform(), "cpu-stub");
    }

    #[test]
    fn loading_missing_artifact_is_a_read_error() {
        let rt = Runtime::cpu().unwrap();
        let err = rt.load_hlo_text("/definitely/not/here.hlo.txt").unwrap_err();
        assert!(format!("{err:#}").contains("reading HLO artifact"), "{err:#}");
    }

    #[test]
    fn loading_existing_artifact_reports_missing_backend() {
        let dir = std::env::temp_dir().join(format!("hlo_stub_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.hlo.txt");
        std::fs::write(&p, "HloModule m\n").unwrap();
        let rt = Runtime::cpu().unwrap();
        let err = rt.load_hlo_text(&p).unwrap_err();
        assert!(format!("{err:#}").contains("PJRT backend not built"), "{err:#}");
    }
}
