//! PJRT runtime: load AOT HLO-text artifacts and execute them from the
//! serving hot path. Wraps the `xla` crate (PJRT C API, CPU client).
//!
//! One [`Runtime`] per process; one [`CompiledModel`] per (arch, dataset,
//! batch) artifact, shareable across worker threads (`Send + Sync` — the
//! PJRT C API is documented thread-safe and the TFRT CPU client supports
//! concurrent `Execute` calls; the `xla` crate types are `!Send` only
//! because they hold raw pointers).

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

use super::artifacts::ModelEntry;

/// Process-wide PJRT client handle.
pub struct Runtime {
    client: xla::PjRtClient,
}

// SAFETY: the PJRT C API guarantees thread-safe clients/executables
// (see PJRT C API header contract); the wrapper types only hold opaque
// pointers into that API.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        log::info!("compiled {path:?} in {:.2}s", t0.elapsed().as_secs_f64());
        Ok(Executable { exe })
    }
}

/// A compiled PJRT executable (thin wrapper; see [`CompiledModel`] for the
/// typed model interface).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

// SAFETY: see Runtime.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with f32 inputs; returns the elements of the ROOT tuple.
    pub fn run(&self, inputs: &[(&[usize], &[f32])]) -> Result<Vec<Tensor>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (shape, data) in inputs {
            let dims: Vec<usize> = shape.to_vec();
            let byte_len = data.len() * 4;
            let bytes =
                unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, byte_len) };
            let lit = xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &dims,
                bytes,
            )
            .context("building input literal")?;
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals).context("PJRT execute")?;
        let root = result[0][0].to_literal_sync().context("fetching result")?;
        // aot.py lowers with return_tuple=True.
        let parts = root.to_tuple().context("untupling result")?;
        let mut out = Vec::with_capacity(parts.len());
        for part in parts {
            let shape = part.array_shape().context("result shape")?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data = part.to_vec::<f32>().context("result data")?;
            out.push(Tensor::from_vec(&dims, data));
        }
        Ok(out)
    }
}

/// A hosted model `f`, compiled for a fixed batch size.
pub struct CompiledModel {
    exe: Executable,
    /// `[batch, H, W, C]`.
    pub input: Vec<usize>,
    pub num_classes: usize,
    pub arch: String,
    pub dataset: String,
}

impl CompiledModel {
    /// Load from a manifest entry.
    pub fn load(rt: &Runtime, root: &Path, entry: &ModelEntry) -> Result<CompiledModel> {
        let exe = rt.load_hlo_text(root.join(&entry.path))?;
        Ok(CompiledModel {
            exe,
            input: entry.input.clone(),
            num_classes: entry.num_classes,
            arch: entry.arch.clone(),
            dataset: entry.dataset.clone(),
        })
    }

    pub fn batch(&self) -> usize {
        self.input[0]
    }

    /// Payload size per query (H·W·C).
    pub fn payload(&self) -> usize {
        self.input[1..].iter().product()
    }

    /// Run inference on a `(B, H, W, C)` batch; returns `(B, num_classes)`
    /// logits. The batch dimension must match the compiled batch exactly
    /// (pad with [`CompiledModel::infer_padded`] otherwise).
    pub fn infer(&self, x: &Tensor) -> Result<Tensor> {
        if x.shape() != self.input.as_slice() {
            bail!(
                "input shape {:?} != compiled shape {:?} ({}/{})",
                x.shape(),
                self.input,
                self.arch,
                self.dataset
            );
        }
        let mut out = self.exe.run(&[(&self.input, x.data())])?;
        if out.len() != 1 {
            bail!("expected 1 output, got {}", out.len());
        }
        Ok(out.remove(0))
    }

    /// Run inference on the first `n ≤ batch` rows of a padded batch: input
    /// has any leading count, it is zero-padded/truncated to the compiled
    /// batch, and only the first `n` logit rows are returned.
    pub fn infer_padded(&self, x: &Tensor, n: usize) -> Result<Tensor> {
        let b = self.batch();
        if n > b {
            bail!("n={n} exceeds compiled batch {b}");
        }
        let payload = self.payload();
        let mut buf = vec![0.0f32; b * payload];
        let take = n.min(x.shape()[0]) * payload;
        buf[..take].copy_from_slice(&x.data()[..take]);
        let padded = Tensor::from_vec(&self.input, buf);
        let logits = self.infer(&padded)?;
        let c = self.num_classes;
        Ok(Tensor::from_vec(&[n, c], logits.data()[..n * c].to_vec()))
    }
}

/// A compiled Pallas Berrut encoder: `(K, D) -> (N+1, D)`.
pub struct CompiledEncoder {
    exe: Executable,
    pub k: usize,
    pub workers: usize,
    pub payload: usize,
}

impl CompiledEncoder {
    pub fn load(
        rt: &Runtime,
        root: &Path,
        entry: &super::artifacts::EncoderEntry,
    ) -> Result<CompiledEncoder> {
        let exe = rt.load_hlo_text(root.join(&entry.path))?;
        let workers = if entry.e == 0 {
            entry.k + entry.s
        } else {
            2 * (entry.k + entry.e) + entry.s
        };
        Ok(CompiledEncoder { exe, k: entry.k, workers, payload: entry.payload })
    }

    /// Encode `(K, D)` flattened queries into `(N+1, D)` coded payloads.
    pub fn encode(&self, queries: &Tensor) -> Result<Tensor> {
        if queries.shape() != [self.k, self.payload] {
            bail!(
                "encoder input shape {:?} != [{}, {}]",
                queries.shape(),
                self.k,
                self.payload
            );
        }
        let mut out = self.exe.run(&[(&[self.k, self.payload], queries.data())])?;
        Ok(out.remove(0))
    }
}
