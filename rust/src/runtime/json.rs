//! Minimal JSON parser for the artifact manifest (no `serde`/`serde_json`
//! in this environment). Supports the full JSON value grammar the python
//! exporter emits: objects, arrays, strings (with escapes), numbers, bools,
//! null. Not streaming — manifests are a few KiB.

use std::collections::BTreeMap;
use std::fmt;

/// Parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// Convenience: `obj.str_field("name")` with a descriptive error.
    pub fn str_field(&self, key: &str) -> Result<String, JsonError> {
        self.get(key)
            .and_then(|v| v.as_str())
            .map(|s| s.to_string())
            .ok_or_else(|| JsonError { pos: 0, msg: format!("missing string field '{key}'") })
    }

    pub fn usize_field(&self, key: &str) -> Result<usize, JsonError> {
        self.get(key)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| JsonError { pos: 0, msg: format!("missing numeric field '{key}'") })
    }

    pub fn f64_field(&self, key: &str) -> Result<f64, JsonError> {
        self.get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| JsonError { pos: 0, msg: format!("missing numeric field '{key}'") })
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => write!(f, "{x}"),
            Json::Str(s) => write!(f, "{s:?}"),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{k:?}:{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let rest = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        loop {
            match self.peek() {
                Some(c) if c.is_ascii_digit() => self.pos += 1,
                Some(b'.' | b'e' | b'E' | b'+' | b'-') => self.pos += 1,
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { pos: start, msg: format!("bad number '{text}'") })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_structure() {
        let text = r#"{
          "version": 1,
          "models": [
            {"arch": "resnet18_s", "dataset": "synmnist", "batch": 1,
             "path": "models/resnet18_s_synmnist_b1.hlo.txt",
             "input": [1, 28, 28, 1], "base_test_acc": 0.9921}
          ],
          "flag": true, "nothing": null
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        let m = &v.get("models").unwrap().as_arr().unwrap()[0];
        assert_eq!(m.str_field("arch").unwrap(), "resnet18_s");
        assert_eq!(m.usize_field("batch").unwrap(), 1);
        assert!((m.f64_field("base_test_acc").unwrap() - 0.9921).abs() < 1e-12);
        let arr = m.get("input").unwrap().as_arr().unwrap();
        let input: Vec<usize> = arr.iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(input, vec![1, 28, 28, 1]);
        assert_eq!(v.get("flag"), Some(&Json::Bool(true)));
        assert_eq!(v.get("nothing"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\"b\\c\nA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\nA"));
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(Json::parse("0").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn nested_arrays() {
        let v = Json::parse("[[1,2],[3]]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_arr().unwrap().len(), 2);
        assert_eq!(a[1].as_arr().unwrap()[0].as_f64(), Some(3.0));
    }
}
