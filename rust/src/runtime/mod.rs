//! Runtime layer: PJRT client wrapper (load + execute AOT HLO-text
//! artifacts), the artifact manifest/tensor-container readers, and the
//! minimal JSON parser they rely on. This is the only module that touches
//! the `xla` crate; everything above it works with plain [`crate::tensor`]
//! payloads.

pub mod artifacts;
pub mod json;
pub mod model;

pub use artifacts::{read_tensor_f32, read_tensor_i32, Manifest, ModelEntry};
pub use json::Json;
pub use model::{CompiledEncoder, CompiledModel, Executable, Runtime};
