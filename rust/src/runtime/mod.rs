//! Runtime layer: the PJRT execution facade (load + execute AOT HLO-text
//! artifacts — currently a stub, see [`model`]), the artifact
//! manifest/tensor-container readers, and the minimal JSON parser they rely
//! on. Everything above it works with plain [`crate::tensor`] payloads.

pub mod artifacts;
pub mod json;
pub mod model;

pub use artifacts::{read_tensor_f32, read_tensor_i32, Manifest, ModelEntry};
pub use json::Json;
pub use model::{CompiledEncoder, CompiledModel, Executable, Runtime};
