//! Artifact loading: the manifest index and the `AXT1` binary tensor
//! container shared with the python build path
//! (`python/compile/datasets.py::export_binary`).

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

use super::json::Json;

/// One AOT-compiled model artifact (weights baked in).
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub arch: String,
    pub dataset: String,
    pub batch: usize,
    /// Path relative to the artifacts root.
    pub path: String,
    /// Input shape `[batch, H, W, C]`.
    pub input: Vec<usize>,
    pub num_classes: usize,
    /// Test accuracy of the hosted model (the paper's "best case" line).
    pub base_test_acc: f64,
    pub param_count: usize,
}

/// One exported dataset test split.
#[derive(Clone, Debug)]
pub struct DatasetEntry {
    pub name: String,
    pub images: String,
    pub labels: String,
    pub count: usize,
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub num_classes: usize,
}

/// One AOT-compiled Pallas encoder artifact.
#[derive(Clone, Debug)]
pub struct EncoderEntry {
    pub k: usize,
    pub s: usize,
    pub e: usize,
    pub payload: usize,
    pub path: String,
}

/// One golden cross-language test-vector set.
#[derive(Clone, Debug)]
pub struct GoldenEntry {
    pub k: usize,
    pub s: usize,
    pub e: usize,
    pub tag: String,
    pub payload: usize,
}

/// Parsed `artifacts/manifest.json` plus the root directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub models: Vec<ModelEntry>,
    pub datasets: Vec<DatasetEntry>,
    pub encoders: Vec<EncoderEntry>,
    pub golden: Vec<GoldenEntry>,
}

impl Manifest {
    /// Load from an artifacts directory (default `artifacts/`).
    pub fn load(root: impl AsRef<Path>) -> Result<Manifest> {
        let root = root.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = Json::parse(&text).context("parsing manifest.json")?;
        let mut models = Vec::new();
        for m in v.get("models").and_then(Json::as_arr).unwrap_or(&[]) {
            models.push(ModelEntry {
                arch: m.str_field("arch")?,
                dataset: m.str_field("dataset")?,
                batch: m.usize_field("batch")?,
                path: m.str_field("path")?,
                input: m
                    .get("input")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default(),
                num_classes: m.usize_field("num_classes")?,
                base_test_acc: m.f64_field("base_test_acc")?,
                param_count: m.usize_field("param_count").unwrap_or(0),
            });
        }
        let mut datasets = Vec::new();
        for d in v.get("datasets").and_then(Json::as_arr).unwrap_or(&[]) {
            datasets.push(DatasetEntry {
                name: d.str_field("name")?,
                images: d.str_field("images")?,
                labels: d.str_field("labels")?,
                count: d.usize_field("count")?,
                height: d.usize_field("height")?,
                width: d.usize_field("width")?,
                channels: d.usize_field("channels")?,
                num_classes: d.usize_field("num_classes")?,
            });
        }
        let mut encoders = Vec::new();
        for e in v.get("encoders").and_then(Json::as_arr).unwrap_or(&[]) {
            encoders.push(EncoderEntry {
                k: e.usize_field("k")?,
                s: e.usize_field("s")?,
                e: e.usize_field("e")?,
                payload: e.usize_field("payload")?,
                path: e.str_field("path")?,
            });
        }
        let mut golden = Vec::new();
        for g in v.get("golden").and_then(Json::as_arr).unwrap_or(&[]) {
            golden.push(GoldenEntry {
                k: g.usize_field("k")?,
                s: g.usize_field("s")?,
                e: g.usize_field("e")?,
                tag: g.str_field("tag")?,
                payload: g.usize_field("payload")?,
            });
        }
        Ok(Manifest { root, models, datasets, encoders, golden })
    }

    /// Find the model artifact for (arch, dataset, batch).
    pub fn model(&self, arch: &str, dataset: &str, batch: usize) -> Result<&ModelEntry> {
        self.models
            .iter()
            .find(|m| m.arch == arch && m.dataset == dataset && m.batch == batch)
            .with_context(|| format!("no artifact for {arch}/{dataset} b{batch}"))
    }

    pub fn dataset(&self, name: &str) -> Result<&DatasetEntry> {
        self.datasets
            .iter()
            .find(|d| d.name == name)
            .with_context(|| format!("no dataset '{name}' in manifest"))
    }

    /// Absolute path of a manifest-relative artifact path.
    pub fn abspath(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }
}

/// Read an `AXT1` f32 tensor file.
pub fn read_tensor_f32(path: impl AsRef<Path>) -> Result<Tensor> {
    let (shape, body) = read_axt(path.as_ref())?;
    let mut data = Vec::with_capacity(body.len() / 4);
    for chunk in body.chunks_exact(4) {
        data.push(f32::from_le_bytes(chunk.try_into().unwrap()));
    }
    Ok(Tensor::from_vec(&shape, data))
}

/// Read an `AXT1` i32 tensor file (labels, index sets).
pub fn read_tensor_i32(path: impl AsRef<Path>) -> Result<(Vec<usize>, Vec<i32>)> {
    let (shape, body) = read_axt(path.as_ref())?;
    let mut data = Vec::with_capacity(body.len() / 4);
    for chunk in body.chunks_exact(4) {
        data.push(i32::from_le_bytes(chunk.try_into().unwrap()));
    }
    Ok((shape, data))
}

fn read_axt(path: &Path) -> Result<(Vec<usize>, Vec<u8>)> {
    let raw = fs::read(path).with_context(|| format!("reading tensor {path:?}"))?;
    if raw.len() < 8 || &raw[..4] != b"AXT1" {
        bail!("{path:?}: not an AXT1 tensor file");
    }
    let ndim = u32::from_le_bytes(raw[4..8].try_into().unwrap()) as usize;
    if raw.len() < 8 + 4 * ndim {
        bail!("{path:?}: truncated header");
    }
    let mut shape = Vec::with_capacity(ndim);
    for i in 0..ndim {
        let off = 8 + 4 * i;
        shape.push(u32::from_le_bytes(raw[off..off + 4].try_into().unwrap()) as usize);
    }
    let body = raw[8 + 4 * ndim..].to_vec();
    let expect: usize = shape.iter().product::<usize>() * 4;
    if body.len() != expect {
        bail!("{path:?}: body {} bytes, expected {expect}", body.len());
    }
    Ok((shape, body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_axt(path: &Path, shape: &[u32], data: &[f32]) {
        let mut f = fs::File::create(path).unwrap();
        f.write_all(b"AXT1").unwrap();
        f.write_all(&(shape.len() as u32).to_le_bytes()).unwrap();
        for &d in shape {
            f.write_all(&d.to_le_bytes()).unwrap();
        }
        for &x in data {
            f.write_all(&x.to_le_bytes()).unwrap();
        }
    }

    #[test]
    fn axt_roundtrip() {
        let dir = std::env::temp_dir().join("axt_test");
        fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.bin");
        write_axt(&p, &[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let t = read_tensor_f32(&p).unwrap();
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.data(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn axt_rejects_bad_magic() {
        let dir = std::env::temp_dir().join("axt_test2");
        fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        fs::write(&p, b"NOPE....").unwrap();
        assert!(read_tensor_f32(&p).is_err());
    }

    #[test]
    fn axt_rejects_truncated_body() {
        let dir = std::env::temp_dir().join("axt_test3");
        fs::create_dir_all(&dir).unwrap();
        let p = dir.join("trunc.bin");
        let mut f = fs::File::create(&p).unwrap();
        f.write_all(b"AXT1").unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        f.write_all(&3u32.to_le_bytes()).unwrap();
        f.write_all(&3u32.to_le_bytes()).unwrap();
        f.write_all(&[0u8; 8]).unwrap(); // 9 floats expected, 2 provided
        drop(f);
        assert!(read_tensor_f32(&p).is_err());
    }

    #[test]
    fn manifest_parse_from_synthetic_json() {
        let dir = std::env::temp_dir().join(format!("man_test_{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,
                "models":[{"arch":"a","dataset":"d","batch":1,"path":"m.hlo.txt",
                           "input":[1,2,2,1],"num_classes":10,"base_test_acc":0.5,
                           "param_count": 7}],
                "datasets":[{"name":"d","images":"i.bin","labels":"l.bin","count":4,
                             "height":2,"width":2,"channels":1,"num_classes":10}],
                "encoders":[], "golden":[]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.models.len(), 1);
        assert_eq!(m.model("a", "d", 1).unwrap().input, vec![1, 2, 2, 1]);
        assert!(m.model("a", "d", 64).is_err());
        assert_eq!(m.dataset("d").unwrap().count, 4);
        assert!(m.dataset("nope").is_err());
    }

    #[test]
    fn manifest_missing_file_is_helpful() {
        let err = Manifest::load("/definitely/not/here").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
