//! Online-service throughput benchmarks: the batcher + concurrent
//! coordinator + worker-pool stack under open-loop load with mock engines
//! (model cost controlled), sweeping K, the flush deadline, `max_inflight`
//! (the number of K-groups the coordinator keeps in flight at once) and —
//! new with the scheme-agnostic engine — the serving scheme itself at
//! matched worker counts (ApproxIFER vs replication vs uncoded).
//!
//! Quick mode (`APPROXIFER_BENCH_QUICK=1`) shrinks request counts for CI
//! smoke runs; `BENCH_PR_JSON=path` additionally writes the max_inflight
//! and scheme sweeps as a JSON artifact so the perf trajectory accumulates
//! across PRs.

use std::io::Write;
use std::sync::Arc;
use std::time::{Duration, Instant};

use approxifer::coding::linalg::{gemm_sweep, GemmSweepRow};
use approxifer::coding::{
    ApproxIferCode, BlockPool, CodeParams, GroupBlock, NerccCode, NerccParams, Replication,
    ServingScheme, Uncoded, VerifyPolicy,
};
use approxifer::coordinator::Service;
use approxifer::harness::latency::{drifting_comparison, DriftRow};
use approxifer::sim::faults::FaultProfile;
use approxifer::sim::{run_scenario, Arrivals, ScenarioReport};
use approxifer::util::bench::quick_mode;
use approxifer::workers::{DelayMockEngine, InferenceEngine, LatencyModel, LinearMockEngine};

struct SweepRow {
    max_inflight: usize,
    report: ScenarioReport,
}

struct FaultRow {
    profile: &'static str,
    report: ScenarioReport,
    corrupt_injected: u64,
    verify_failures: u64,
    redispatches: u64,
}

struct SchemeRow {
    name: String,
    workers: usize,
    k: usize,
    report: ScenarioReport,
}

struct HealthRow {
    fleet: &'static str,
    report: ScenarioReport,
    corrupt_injected: u64,
    verify_failures: u64,
    quarantines: u64,
    effective_overhead: f64,
}

fn main() {
    let quick = quick_mode();
    let scale = if quick { 4 } else { 1 };
    let (d, c) = (128usize, 10usize);

    println!("\n== service throughput (open-loop, 0.1ms model, no tail) ==");
    println!(
        "{:<26} {:>8} {:>12} {:>12} {:>12}",
        "config", "requests", "thrpt/s", "p50_ms", "p99_ms"
    );
    for &k in &[4usize, 8, 12] {
        let engine: Arc<dyn InferenceEngine> =
            Arc::new(DelayMockEngine::new(d, c, Duration::from_micros(100)));
        let service = Arc::new(
            Service::builder(Arc::new(ApproxIferCode::new(CodeParams::new(k, 1, 0))))
                .engine(engine)
                .flush_after(Duration::from_millis(5))
                .spawn()
                .unwrap(),
        );
        let total = 512 / scale;
        let report =
            run_scenario(&service, d, total, Arrivals::Uniform { rate: 1e6 }, 42).unwrap();
        println!(
            "{:<26} {:>8} {:>12.1} {:>12.2} {:>12.2}",
            format!("approxifer_k{k}_s1"),
            report.sent,
            report.throughput,
            report.latency.p50 * 1e3,
            report.latency.p99 * 1e3
        );
    }

    println!("\n== flush-deadline sweep (K=8, sparse arrivals 200/s) ==");
    println!("{:<26} {:>12} {:>12} {:>12}", "flush_after", "thrpt/s", "p50_ms", "p99_ms");
    for &ms in &[2u64, 10, 50] {
        let engine: Arc<dyn InferenceEngine> =
            Arc::new(DelayMockEngine::new(d, c, Duration::from_micros(100)));
        let service = Arc::new(
            Service::builder(Arc::new(ApproxIferCode::new(CodeParams::new(8, 1, 0))))
                .engine(engine)
                .flush_after(Duration::from_millis(ms))
                .spawn()
                .unwrap(),
        );
        let total = 256 / scale;
        let report =
            run_scenario(&service, d, total, Arrivals::Poisson { rate: 200.0 }, 43).unwrap();
        println!(
            "{:<26} {:>12.1} {:>12.2} {:>12.2}",
            format!("{ms}ms"),
            report.throughput,
            report.latency.p50 * 1e3,
            report.latency.p99 * 1e3
        );
    }

    // ---- the headline: concurrent scheduler vs serial coordinator --------
    // N = 10 simulated workers (K=9, S=1) with a bimodal service tail:
    // 1 ms base, 25 ms straggler with p = 0.2. A serial coordinator pays
    // the 9th-of-10 order statistic per group (a ~25 ms stall whenever two
    // or more workers straggle, p ≈ 0.62); the pipelined coordinator keeps
    // the workers busy across groups so throughput approaches the
    // per-worker service rate instead.
    let rows = max_inflight_sweep(d, c, if quick { 27 } else { 90 });
    let base = rows[0].report.throughput;
    println!("\nspeedup vs max_inflight=1:");
    for row in &rows[1..] {
        println!(
            "  max_inflight={}: {:.2}x",
            row.max_inflight,
            row.report.throughput / base
        );
    }
    // ---- robustness overhead: the fault-profile matrix -------------------
    let fault_rows = fault_profile_sweep(d, c, if quick { 27 } else { 90 });

    // ---- scheme comparison at matched worker counts ----------------------
    let scheme_rows = scheme_comparison_sweep(d, c, if quick { 27 } else { 90 });

    // ---- adaptive control plane on the drifting-fault trace --------------
    let adaptive_rows = adaptive_drift_sweep(d, c, if quick { 10 } else { 40 });

    // ---- worker health plane vs memoryless fleet under a persistent
    //      adversary -------------------------------------------------------
    let health_rows = health_plane_sweep(d, c, if quick { 27 } else { 90 });

    // ---- codec GEMM baseline: naive vs cache-blocked ----------------------
    println!("\n== codec GEMM micro-kernel sweep (naive vs blocked, linalg_rows) ==");
    println!(
        "{:<6} {:>6} {:>6} {:>12} {:>12} {:>9}",
        "K", "d", "rows", "naive_us", "blocked_us", "speedup"
    );
    let linalg_rows = gemm_sweep(quick);
    for r in &linalg_rows {
        println!(
            "{:<6} {:>6} {:>6} {:>12.2} {:>12.2} {:>8.2}x",
            r.k, r.d, r.m, r.naive_us, r.blocked_us, r.speedup
        );
    }

    if let Some(path) = std::env::var_os("BENCH_PR_JSON") {
        write_json(
            &path,
            d,
            &rows,
            &fault_rows,
            &scheme_rows,
            &adaptive_rows,
            &health_rows,
            &linalg_rows,
        );
    }

    println!("\n== encode throughput ceiling (host-side flat path, K=8 S=1, d=3072) ==");
    {
        let code = ApproxIferCode::new(CodeParams::new(8, 1, 0));
        let qs: Vec<Vec<f32>> = (0..8).map(|j| vec![j as f32 * 0.1; 3072]).collect();
        let qrefs: Vec<&[f32]> = qs.iter().map(|q| &q[..]).collect();
        let queries = GroupBlock::from_rows(&qrefs);
        let pool = BlockPool::new();
        let t0 = Instant::now();
        let iters = if quick { 2_000 } else { 20_000 };
        for _ in 0..iters {
            // The serving batcher's exact shape: pooled take → GEMM →
            // freeze → retire (drop recycles the block).
            let mut out = pool.take(9, 3072);
            code.encode_block(&queries, &mut out);
            std::hint::black_box(out.freeze());
        }
        let per = t0.elapsed().as_secs_f64() / iters as f64;
        println!(
            "encode_block: {:.1}us/group -> {:.0} groups/s ({:.0} queries/s, pool reuse {})",
            per * 1e6,
            1.0 / per,
            8.0 / per,
            pool.reused()
        );
    }
}

/// Sweep `max_inflight` at N=10 workers under a straggler-prone tail;
/// `groups` K-groups of load per point.
fn max_inflight_sweep(d: usize, c: usize, groups: usize) -> Vec<SweepRow> {
    let params = CodeParams::new(9, 1, 0); // N+1 = 10 workers
    let total = groups * params.k;
    println!(
        "\n== max_inflight sweep (N={} workers, K={}, bimodal 1ms/25ms p=0.2 tail) ==",
        params.num_workers(),
        params.k
    );
    println!(
        "{:<16} {:>8} {:>12} {:>12} {:>12} {:>14}",
        "max_inflight", "requests", "thrpt/s", "p50_ms", "p99_ms", "inflight_waits"
    );
    let mut rows = Vec::new();
    for &mi in &[1usize, 2, 4, 8] {
        let engine: Arc<dyn InferenceEngine> = Arc::new(LinearMockEngine::new(d, c));
        let service = Arc::new(
            Service::builder(Arc::new(ApproxIferCode::new(params)))
                .engine(engine)
                .flush_after(Duration::from_millis(2))
                .max_inflight(mi)
                .decode_threads(2)
                .worker_latency(LatencyModel::Bimodal {
                    base_ms: 1.0,
                    straggler_ms: 25.0,
                    p: 0.2,
                })
                .spawn()
                .unwrap(),
        );
        // Bursty with one giant burst = submit everything immediately: a
        // pure open-loop flood that exposes the pipeline depth.
        let arrivals = Arrivals::Bursty { burst: total, period_ms: 0.0 };
        let report = run_scenario(&service, d, total, arrivals, 4242).unwrap();
        println!(
            "{:<16} {:>8} {:>12.1} {:>12.2} {:>12.2} {:>14}",
            mi,
            report.sent,
            report.throughput,
            report.latency.p50 * 1e3,
            report.latency.p99 * 1e3,
            service.metrics.inflight_full_waits.get()
        );
        rows.push(SweepRow { max_inflight: mi, report });
    }
    rows
}

/// Sweep the named fault profiles at fixed code (K=4, S=1, E=1 → 11
/// workers, wait for 10) with decode verification on, so CI tracks the
/// robustness overhead — locate + verify cost, redispatches, and failure
/// rates under churn — alongside raw throughput.
fn fault_profile_sweep(d: usize, c: usize, groups: usize) -> Vec<FaultRow> {
    let params = CodeParams::new(4, 1, 1);
    let nw = params.num_workers();
    let total = groups * params.k;
    println!(
        "\n== fault-profile sweep (N={} workers, K={} S={} E={}, verify on) ==",
        nw, params.k, params.s, params.e
    );
    println!(
        "{:<22} {:>8} {:>10} {:>10} {:>10} {:>9} {:>11} {:>11}",
        "profile", "ok", "fail", "thrpt/s", "p99_ms", "corrupt", "verify_fail", "redispatch"
    );
    let mut rows = Vec::new();
    for profile in ["honest", "slow:1:25:0:1", "byz-random:1:10", "churn:3"] {
        let engine: Arc<dyn InferenceEngine> = Arc::new(LinearMockEngine::new(d, c));
        let service = Arc::new(
            Service::builder(Arc::new(ApproxIferCode::new(params)))
                .engine(engine)
                .flush_after(Duration::from_millis(2))
                .max_inflight(4)
                .decode_threads(2)
                .verify(VerifyPolicy::on(0.4))
                .group_timeout(Duration::from_secs(5))
                .fault_profile(FaultProfile::parse(profile, nw, 4242).unwrap())
                .spawn()
                .unwrap(),
        );
        let arrivals = Arrivals::Bursty { burst: total, period_ms: 0.0 };
        let report = run_scenario(&service, d, total, arrivals, 77).unwrap();
        let m = &service.metrics;
        println!(
            "{:<22} {:>8} {:>10} {:>10.1} {:>10.2} {:>9} {:>11} {:>11}",
            profile,
            report.completed,
            report.failed,
            report.throughput,
            report.latency.p99 * 1e3,
            m.corrupt_replies_injected.get(),
            m.verify_failures.get(),
            m.redispatches.get()
        );
        rows.push(FaultRow {
            profile,
            corrupt_injected: m.corrupt_replies_injected.get(),
            verify_failures: m.verify_failures.get(),
            redispatches: m.redispatches.get(),
            report,
        });
    }
    rows
}

/// The scheme-agnostic engine's headline: ApproxIFER vs NeRCC vs
/// replication vs uncoded at a matched 10-worker fleet under the same
/// bimodal tail, all through the identical `Service` stack. ApproxIFER and
/// NeRCC each serve K=9 per group on 10 workers (one straggler of slack);
/// replication serves K=5 with 2 copies each; uncoded serves K=10 with no
/// slack (and pays the full 10th-order-statistic tail).
fn scheme_comparison_sweep(d: usize, c: usize, groups: usize) -> Vec<SchemeRow> {
    let schemes: Vec<Arc<dyn ServingScheme>> = vec![
        Arc::new(ApproxIferCode::new(CodeParams::new(9, 1, 0))),
        Arc::new(NerccCode::new(NerccParams::new(9, 1, 0))),
        Arc::new(Replication::new(5, 1, 0)),
        Arc::new(Uncoded::new(10)),
    ];
    println!("\n== scheme sweep (matched 10-worker fleet, bimodal 1ms/25ms p=0.2 tail) ==");
    println!(
        "{:<22} {:>8} {:>8} {:>8} {:>12} {:>12} {:>12}",
        "scheme", "workers", "K", "ok", "thrpt/s", "p50_ms", "p99_ms"
    );
    let mut rows = Vec::new();
    for scheme in schemes {
        let k = scheme.group_size();
        let workers = scheme.num_workers();
        let name = scheme.name().to_string();
        let total = groups * k;
        let engine: Arc<dyn InferenceEngine> = Arc::new(LinearMockEngine::new(d, c));
        let service = Arc::new(
            Service::builder(scheme)
                .engine(engine)
                .flush_after(Duration::from_millis(2))
                .max_inflight(4)
                .decode_threads(2)
                .worker_latency(LatencyModel::Bimodal {
                    base_ms: 1.0,
                    straggler_ms: 25.0,
                    p: 0.2,
                })
                .spawn()
                .unwrap(),
        );
        let arrivals = Arrivals::Bursty { burst: total, period_ms: 0.0 };
        let report = run_scenario(&service, d, total, arrivals, 909).unwrap();
        println!(
            "{:<22} {:>8} {:>8} {:>8} {:>12.1} {:>12.2} {:>12.2}",
            name,
            workers,
            k,
            report.completed,
            report.throughput,
            report.latency.p50 * 1e3,
            report.latency.p99 * 1e3
        );
        rows.push(SchemeRow { name, workers, k, report });
    }
    rows
}

/// The health plane's headline: one worker corrupts every reply for the
/// whole run (a persistent adversary, not a burst). The memoryless fleet
/// pays the locate + verify ladder on every group forever; the
/// health-plane fleet convicts the slot within a few groups, quarantines
/// it, and backfills from a spare, after which groups are clean. Both arms
/// run the identical service stack, scheme, and load; `ovh` is worker
/// tasks delivered per completed query (probe duplicates excluded).
fn health_plane_sweep(d: usize, c: usize, groups: usize) -> Vec<HealthRow> {
    use approxifer::sim::faults::Behavior;
    use approxifer::workers::{
        ByzantineMode, HealthConfig, HealthGate, HealthPlane, WorkerPool, WorkerSpec,
    };
    let params = CodeParams::new(4, 0, 1); // 10 workers, every reply collected
    let nw = params.num_workers();
    let total = groups * params.k;
    println!(
        "\n== worker health plane (persistent adversary at slot 2, N={nw} K={} E=1, \
         verify on) ==",
        params.k
    );
    println!(
        "{:<16} {:>8} {:>10} {:>10} {:>9} {:>12} {:>12} {:>7}",
        "fleet", "ok", "thrpt/s", "p99_ms", "corrupt", "verify_fail", "quarantines", "ovh"
    );
    let mut rows = Vec::new();
    for &(label, gated) in &[("memoryless", false), ("health-plane", true)] {
        let engine: Arc<dyn InferenceEngine> = Arc::new(LinearMockEngine::new(d, c));
        // The gated arm carries one honest spare as backfill capacity.
        let width = if gated { nw + 1 } else { nw };
        let mut specs = vec![WorkerSpec::default(); width];
        specs[2] = WorkerSpec::default().with_behavior(Behavior::Byzantine(
            ByzantineMode::Colluding { pact: 3117, scale: 15.0 },
        ));
        let pool = WorkerPool::spawn(engine, &specs, 4242);
        let mut builder = Service::builder(Arc::new(ApproxIferCode::new(params)));
        let plane = if gated {
            let plane = Arc::new(HealthPlane::new(HealthConfig::default(), 4242));
            let gate = HealthGate::attach(Box::new(pool), nw, plane.clone());
            builder = builder.fleet(Box::new(gate)).health_plane(plane.clone(), 0);
            Some(plane)
        } else {
            builder = builder.fleet(Box::new(pool));
            None
        };
        let service = Arc::new(
            builder
                .flush_after(Duration::from_millis(2))
                // Shallow pipeline: evidence decoded before quarantine can
                // only misattribute the one other in-flight group.
                .max_inflight(2)
                .decode_threads(2)
                .verify(VerifyPolicy::on(0.4))
                .group_timeout(Duration::from_secs(5))
                .spawn()
                .unwrap(),
        );
        let arrivals = Arrivals::Bursty { burst: total, period_ms: 0.0 };
        let report = run_scenario(&service, d, total, arrivals, 2718).unwrap();
        let m = &service.metrics;
        let completed = report.completed.max(1) as f64;
        let effective_overhead = match &plane {
            Some(p) => p.stats().delivered as f64 / completed,
            None => nw as f64 / params.k as f64,
        };
        let quarantines = plane.as_ref().map(|p| p.stats().quarantines).unwrap_or(0);
        println!(
            "{:<16} {:>8} {:>10.1} {:>10.2} {:>9} {:>12} {:>12} {:>6.2}x",
            label,
            report.completed,
            report.throughput,
            report.latency.p99 * 1e3,
            m.corrupt_replies_injected.get(),
            m.verify_failures.get(),
            quarantines,
            effective_overhead
        );
        rows.push(HealthRow {
            fleet: label,
            corrupt_injected: m.corrupt_replies_injected.get(),
            verify_failures: m.verify_failures.get(),
            quarantines,
            effective_overhead,
            report,
        });
    }
    rows
}

/// The adaptive control plane's headline: the drifting-fault trace
/// (honest → slow-burst → byz-burst → recovered) served adaptive vs
/// static-pessimistic vs static-oracle at K=4, provisioned (S=1, E=1).
/// The adaptive run should undercut static-pessimistic worker overhead
/// while tracking static-oracle accuracy.
fn adaptive_drift_sweep(d: usize, c: usize, groups_per_phase: usize) -> Vec<DriftRow> {
    println!(
        "\n== adaptive drift sweep (K=4 provisioned S=1 E=1, slo=15ms, \
         {groups_per_phase} groups/phase) =="
    );
    println!(
        "{:<20} {:<12} {:>10} {:>10} {:>10} {:>13} {:>8}",
        "run", "phase", "p50_ms", "p99_ms", "accuracy", "mean_workers", "(S,E)"
    );
    let engine: Arc<dyn InferenceEngine> = Arc::new(LinearMockEngine::new(d, c));
    let rows = drifting_comparison(engine, 4, groups_per_phase, 20220807)
        .expect("drifting trace failed");
    for r in &rows {
        println!(
            "{:<20} {:<12} {:>10.2} {:>10.2} {:>10.3} {:>13.1} {:>8}",
            r.run,
            r.phase,
            r.p50 * 1e3,
            r.p99 * 1e3,
            r.accuracy,
            r.mean_workers,
            format!("({},{})", r.s, r.e)
        );
    }
    // Whole-trace headline per run (phases are equal-length, so the mean
    // over phases is the trace mean).
    for run in ["adaptive", "static-pessimistic", "static-oracle"] {
        let sel: Vec<&DriftRow> = rows.iter().filter(|r| r.run == run).collect();
        let acc = sel.iter().map(|r| r.accuracy).sum::<f64>() / sel.len().max(1) as f64;
        let workers =
            sel.iter().map(|r| r.mean_workers).sum::<f64>() / sel.len().max(1) as f64;
        let p99 = sel.iter().map(|r| r.p99).fold(0.0f64, f64::max);
        println!(
            "  {run}: trace accuracy {acc:.3}, mean workers {workers:.1}, worst p99 \
             {:.2}ms",
            p99 * 1e3
        );
    }
    rows
}

/// Hand-rolled JSON artifact (no serde in this environment).
#[allow(clippy::too_many_arguments)]
fn write_json(
    path: &std::ffi::OsStr,
    payload: usize,
    rows: &[SweepRow],
    faults: &[FaultRow],
    schemes: &[SchemeRow],
    adaptive: &[DriftRow],
    health: &[HealthRow],
    linalg: &[GemmSweepRow],
) {
    let base = rows[0].report.throughput;
    let mut out = String::from("{\n");
    out.push_str("  \"bench\": \"bench_throughput\",\n");
    out.push_str("  \"workers\": 10,\n  \"k\": 9,\n");
    out.push_str(&format!("  \"payload_floats\": {payload},\n"));
    out.push_str("  \"tail\": \"bimodal base=1ms straggler=25ms p=0.2\",\n");
    out.push_str("  \"rows\": [\n");
    for (i, row) in rows.iter().enumerate() {
        let r = &row.report;
        out.push_str(&format!(
            "    {{\"max_inflight\": {}, \"throughput_rps\": {:.1}, \"p50_ms\": {:.2}, \
             \"p99_ms\": {:.2}, \"completed\": {}, \"failed\": {}}}{}\n",
            row.max_inflight,
            r.throughput,
            r.latency.p50 * 1e3,
            r.latency.p99 * 1e3,
            r.completed,
            r.failed,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"fault_rows\": [\n");
    for (i, row) in faults.iter().enumerate() {
        let r = &row.report;
        out.push_str(&format!(
            "    {{\"profile\": \"{}\", \"throughput_rps\": {:.1}, \"p50_ms\": {:.2}, \
             \"p99_ms\": {:.2}, \"completed\": {}, \"failed\": {}, \"corrupt_injected\": {}, \
             \"verify_failures\": {}, \"redispatches\": {}}}{}\n",
            row.profile,
            r.throughput,
            r.latency.p50 * 1e3,
            r.latency.p99 * 1e3,
            r.completed,
            r.failed,
            row.corrupt_injected,
            row.verify_failures,
            row.redispatches,
            if i + 1 < faults.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"scheme_rows\": [\n");
    for (i, row) in schemes.iter().enumerate() {
        let r = &row.report;
        out.push_str(&format!(
            "    {{\"scheme\": \"{}\", \"workers\": {}, \"k\": {}, \"throughput_rps\": {:.1}, \
             \"p50_ms\": {:.2}, \"p99_ms\": {:.2}, \"completed\": {}, \"failed\": {}}}{}\n",
            row.name,
            row.workers,
            row.k,
            r.throughput,
            r.latency.p50 * 1e3,
            r.latency.p99 * 1e3,
            r.completed,
            r.failed,
            if i + 1 < schemes.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"adaptive_rows\": [\n");
    for (i, row) in adaptive.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"run\": \"{}\", \"phase\": \"{}\", \"p50_ms\": {:.2}, \"p99_ms\": {:.2}, \
             \"accuracy\": {:.4}, \"mean_workers\": {:.2}, \"s\": {}, \"e\": {}}}{}\n",
            row.run,
            row.phase,
            row.p50 * 1e3,
            row.p99 * 1e3,
            row.accuracy,
            row.mean_workers,
            row.s,
            row.e,
            if i + 1 < adaptive.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"health_rows\": [\n");
    for (i, row) in health.iter().enumerate() {
        let r = &row.report;
        out.push_str(&format!(
            "    {{\"fleet\": \"{}\", \"throughput_rps\": {:.1}, \"p50_ms\": {:.2}, \
             \"p99_ms\": {:.2}, \"completed\": {}, \"failed\": {}, \"corrupt_injected\": {}, \
             \"verify_failures\": {}, \"quarantines\": {}, \"effective_overhead\": {:.2}}}{}\n",
            row.fleet,
            r.throughput,
            r.latency.p50 * 1e3,
            r.latency.p99 * 1e3,
            r.completed,
            r.failed,
            row.corrupt_injected,
            row.verify_failures,
            row.quarantines,
            row.effective_overhead,
            if i + 1 < health.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"linalg_rows\": [\n");
    for (i, row) in linalg.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"k\": {}, \"d\": {}, \"rows\": {}, \"naive_us\": {:.3}, \
             \"blocked_us\": {:.3}, \"speedup\": {:.3}}}{}\n",
            row.k,
            row.d,
            row.m,
            row.naive_us,
            row.blocked_us,
            row.speedup,
            if i + 1 < linalg.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n");
    let best =
        rows.iter().map(|r| r.report.throughput).fold(0.0f64, f64::max) / base.max(1e-9);
    out.push_str(&format!("  \"best_speedup_vs_serial\": {best:.2}\n}}\n"));
    match std::fs::File::create(path).and_then(|mut f| f.write_all(out.as_bytes())) {
        Ok(()) => println!("wrote {}", path.to_string_lossy()),
        Err(e) => eprintln!("failed to write bench json: {e}"),
    }
}
