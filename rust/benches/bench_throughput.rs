//! Online-service throughput benchmarks: the batcher + coordinator +
//! worker-pool stack under closed-loop load with mock engines (model cost
//! controlled), sweeping K and the flush deadline.

use std::sync::Arc;
use std::time::{Duration, Instant};

use approxifer::coding::CodeParams;
use approxifer::coordinator::{Service, ServiceConfig};
use approxifer::sim::{run_scenario, Arrivals};
use approxifer::workers::{DelayMockEngine, InferenceEngine, WorkerSpec};

fn main() {
    let (d, c) = (128usize, 10usize);
    println!("\n== service throughput (closed-loop, 0.1ms model, no tail) ==");
    println!(
        "{:<26} {:>8} {:>12} {:>12} {:>12}",
        "config", "requests", "thrpt/s", "p50_ms", "p99_ms"
    );
    for &k in &[4usize, 8, 12] {
        let params = CodeParams::new(k, 1, 0);
        let engine: Arc<dyn InferenceEngine> =
            Arc::new(DelayMockEngine::new(d, c, Duration::from_micros(100)));
        let mut cfg = ServiceConfig::new(params);
        cfg.flush_after = Duration::from_millis(5);
        cfg.worker_specs = vec![WorkerSpec::default(); params.num_workers()];
        let service = Arc::new(Service::start(engine, cfg));
        let report =
            run_scenario(&service, d, 512, Arrivals::Uniform { rate: 1e6 }, 42).unwrap();
        println!(
            "{:<26} {:>8} {:>12.1} {:>12.2} {:>12.2}",
            format!("approxifer_k{k}_s1"),
            report.sent,
            report.throughput,
            report.latency.p50 * 1e3,
            report.latency.p99 * 1e3
        );
    }

    println!("\n== flush-deadline sweep (K=8, sparse arrivals 200/s) ==");
    println!("{:<26} {:>12} {:>12} {:>12}", "flush_after", "thrpt/s", "p50_ms", "p99_ms");
    for &ms in &[2u64, 10, 50] {
        let params = CodeParams::new(8, 1, 0);
        let engine: Arc<dyn InferenceEngine> =
            Arc::new(DelayMockEngine::new(d, c, Duration::from_micros(100)));
        let mut cfg = ServiceConfig::new(params);
        cfg.flush_after = Duration::from_millis(ms);
        let service = Arc::new(Service::start(engine, cfg));
        let report =
            run_scenario(&service, d, 256, Arrivals::Poisson { rate: 200.0 }, 43).unwrap();
        println!(
            "{:<26} {:>12.1} {:>12.2} {:>12.2}",
            format!("{ms}ms"),
            report.throughput,
            report.latency.p50 * 1e3,
            report.latency.p99 * 1e3
        );
    }

    println!("\n== encode throughput ceiling (host-side, K=8 S=1, d=3072) ==");
    {
        use approxifer::coding::ApproxIferCode;
        let code = ApproxIferCode::new(CodeParams::new(8, 1, 0));
        let qs: Vec<Vec<f32>> = (0..8).map(|j| vec![j as f32 * 0.1; 3072]).collect();
        let qrefs: Vec<&[f32]> = qs.iter().map(|q| &q[..]).collect();
        let mut out: Vec<Vec<f32>> = vec![Vec::new(); 9];
        let t0 = Instant::now();
        let iters = 20_000;
        for _ in 0..iters {
            code.encode_into(&qrefs, &mut out);
        }
        let per = t0.elapsed().as_secs_f64() / iters as f64;
        println!(
            "encode_into: {:.1}us/group -> {:.0} groups/s ({:.0} queries/s)",
            per * 1e6,
            1.0 / per,
            8.0 / per
        );
    }
}
