//! Linear-algebra substrate benchmarks: the f32 codec GEMM micro-kernel
//! (naive vs cache-blocked, the `linalg_rows` perf baseline) and the f64
//! locator solvers (QR least-squares and Jacobi SVD at ≤ ~60×30).

use approxifer::coding::linalg::gemm_sweep;
use approxifer::linalg::{lstsq, min_norm_solution, Mat, Qr};
use approxifer::util::bench::{bench, black_box, group, quick_mode};
use approxifer::util::rng::Rng;

fn random_mat(m: usize, n: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(m, n, |_, _| rng.range_f64(-2.0, 2.0))
}

fn main() {
    group("codec GEMM micro-kernel: naive vs cache-blocked (linalg_rows sweep)");
    println!(
        "{:<6} {:>6} {:>6} {:>12} {:>12} {:>9}",
        "K", "d", "rows", "naive_us", "blocked_us", "speedup"
    );
    for r in gemm_sweep(quick_mode()) {
        println!(
            "{:<6} {:>6} {:>6} {:>12.2} {:>12.2} {:>8.2}x",
            r.k, r.d, r.m, r.naive_us, r.blocked_us, r.speedup
        );
    }

    group("Householder QR least squares (locator system sizes)");
    for &(m, n) in &[(17usize, 19usize), (28, 27), (31, 29)] {
        // m equations, n unknowns — note the locator pads when m < n is
        // impossible by eq. (3); sizes here are the real (N-S+1, 2(K+E)-1).
        let (m, n) = if m >= n { (m, n) } else { (n, m) };
        let a = random_mat(m, n, 5);
        let b: Vec<f64> = (0..m).map(|i| (i as f64 * 0.37).sin()).collect();
        bench(&format!("lstsq_{m}x{n}"), || {
            black_box(lstsq(black_box(&a), &b).unwrap());
        });
        bench(&format!("qr_factor_{m}x{n}"), || {
            black_box(Qr::factor(black_box(&a)).unwrap());
        });
    }

    group("Jacobi SVD smallest singular vector (homogeneous ablation)");
    for &(m, n) in &[(28usize, 28usize), (31, 30)] {
        let a = random_mat(m, n, 7);
        bench(&format!("min_norm_{m}x{n}"), || {
            black_box(min_norm_solution(black_box(&a)).unwrap());
        });
    }

    group("matmul (decode-matrix application scale)");
    for &(m, k, n) in &[(12usize, 26usize, 10usize), (31, 12, 3072)] {
        let a = random_mat(m, k, 9);
        let b = random_mat(k, n, 10);
        bench(&format!("matmul_{m}x{k}x{n}"), || {
            black_box(a.matmul(black_box(&b)));
        });
    }
}
