//! Open-loop overload bench: goodput and tail latency vs offered load,
//! per scheme and per fault profile, through the admission-controlled
//! online service. Emits `overload_rows` into `BENCH_PR_JSON` (appended to
//! bench_throughput's artifact when it already exists) so the p99-vs-load
//! knee is a tracked regression surface.
//!
//! Every row re-asserts the overload accounting invariant
//! `submitted == served + degraded + shed + rejected + failed` — the
//! harness refuses to return an unbalanced report, which makes the CI
//! smoke run a hard gate on the accounting, not just a perf printout.

use std::sync::Arc;
use std::time::Duration;

use approxifer::coding::{ApproxIferCode, CodeParams, Replication, ServingScheme};
use approxifer::coordinator::{AdmissionConfig, Priority, Service, ShedPolicy};
use approxifer::harness::overload::{drive, LoadTrace, OverloadReport};
use approxifer::sim::faults::FaultProfile;
use approxifer::util::bench::quick_mode;
use approxifer::workers::{DelayMockEngine, InferenceEngine};

const PAYLOAD: usize = 64;
const CLASSES: usize = 8;

fn schemes() -> Vec<(&'static str, Arc<dyn ServingScheme>)> {
    vec![
        ("approxifer(K=4,S=1,E=0)", Arc::new(ApproxIferCode::new(CodeParams::new(4, 1, 0)))),
        ("replication(K=4,S=1,E=0)", Arc::new(Replication::new(4, 1, 0))),
    ]
}

fn service(scheme: Arc<dyn ServingScheme>, faults: Option<&str>, seed: u64) -> Service {
    let engine: Arc<dyn InferenceEngine> =
        Arc::new(DelayMockEngine::new(PAYLOAD, CLASSES, Duration::from_micros(100)));
    let mut builder = Service::builder(scheme.clone())
        .engine(engine)
        .batch_deadline(Duration::from_millis(5))
        .admission(AdmissionConfig {
            queue_depth: 64,
            shed_policy: ShedPolicy::ShedBatch,
            default_priority: Priority::Interactive,
        })
        .seed(seed);
    if let Some(spec) = faults {
        let profile = FaultProfile::parse(spec, scheme.num_workers(), seed)
            .expect("bench fault profile must parse");
        builder = builder.fault_profile(profile);
    }
    builder.spawn().unwrap()
}

fn run_row(
    scheme_label: &str,
    scheme: Arc<dyn ServingScheme>,
    trace: LoadTrace,
    fault_label: &str,
    fault_spec: Option<&str>,
    total: usize,
    seed: u64,
) -> OverloadReport {
    let svc = service(scheme, fault_spec, seed);
    // Every 4th query rides the sheddable batch class so shed:batch has
    // victims under overload.
    let report = drive(&svc, &trace, total, PAYLOAD, seed, 4, scheme_label, fault_label)
        .expect("overload accounting must balance");
    svc.shutdown();
    // The per-class tail split must partition the successes — every
    // served/degraded query is interactive xor batch, never both/neither.
    assert_eq!(
        report.interactive.count + report.batch.count,
        report.served + report.degraded,
        "per-class latency split must partition the successes: {}",
        report.line()
    );
    if fault_spec.is_none() {
        assert_eq!(
            report.failed, 0,
            "an honest fleet must not fail queries downstream: {}",
            report.line()
        );
    }
    report
}

fn main() {
    let quick = quick_mode();
    let total = if quick { 160 } else { 1200 };
    let mut rows: Vec<OverloadReport> = Vec::new();

    println!("== open-loop overload: goodput + tail vs offered load ==");
    println!("(requests/row: {total}; every 4th query batch-priority; queue_depth=64)");

    // The offered-load curve: a Poisson rate sweep straddling the knee.
    let rates: &[f64] = if quick { &[500.0, 4000.0] } else { &[500.0, 1500.0, 4000.0, 8000.0] };
    for (label, scheme) in schemes() {
        for &rate in rates {
            let r = run_row(
                label,
                scheme.clone(),
                LoadTrace::Poisson { rate },
                "honest",
                None,
                total,
                11,
            );
            println!("{}", r.line());
            rows.push(r);
        }
    }

    // The arrival shapes at a fixed mid-sweep intensity.
    let shaped: &[LoadTrace] = &[
        LoadTrace::Diurnal { low: 200.0, high: 4000.0, period_s: 0.5 },
        LoadTrace::OnOff { rate: 6000.0, on_ms: 40.0, off_ms: 120.0 },
        LoadTrace::FlashCrowd { base: 400.0, spike: 8000.0, at_ms: 100.0, spike_ms: 60.0 },
    ];
    for (label, scheme) in schemes() {
        for trace in shaped {
            let r = run_row(label, scheme.clone(), *trace, "honest", None, total, 13);
            println!("{}", r.line());
            rows.push(r);
        }
    }

    // Straggler fleet (full mode only: the 40ms injected stalls make the
    // rows slow, and the honest matrix already gates the accounting in CI).
    if !quick {
        for (label, scheme) in schemes() {
            let r = run_row(
                label,
                scheme.clone(),
                LoadTrace::Poisson { rate: 1500.0 },
                "slow:1:0:40:0.5",
                Some("slow:1:0:40:0.5"),
                total,
                17,
            );
            println!("{}", r.line());
            rows.push(r);
        }
    }

    for r in &rows {
        assert!(r.accounting_balances(), "unbalanced row: {}", r.line());
    }
    println!("\n{} rows, accounting invariant holds on every one", rows.len());

    if let Some(path) = std::env::var_os("BENCH_PR_JSON") {
        write_json(&path, &rows);
    }
}

/// Append `overload_rows` to the `BENCH_PR_JSON` artifact: spliced into
/// bench_throughput's object when that bench already wrote it (replacing
/// any previous `overload_rows` block on a re-run), standalone otherwise.
fn write_json(path: &std::ffi::OsStr, rows: &[OverloadReport]) {
    let mut body = String::from("  \"overload_rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        body.push_str(&format!(
            "    {}{}\n",
            r.json_row(),
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    body.push_str("  ]\n");
    let out = match std::fs::read_to_string(path) {
        Ok(existing) => {
            let existing = match existing.find(",\n  \"overload_rows\"") {
                Some(pos) => format!("{}\n}}\n", &existing[..pos]),
                None => existing,
            };
            let trimmed = existing.trim_end();
            match trimmed.strip_suffix('}') {
                Some(head) => format!("{},\n{body}}}\n", head.trim_end()),
                // Not an object we understand — don't clobber it.
                None => {
                    eprintln!("BENCH_PR_JSON exists but is not a JSON object; leaving it");
                    return;
                }
            }
        }
        Err(_) => format!("{{\n  \"bench\": \"bench_overload\",\n{body}}}\n"),
    };
    if let Err(e) = std::fs::write(path, out) {
        eprintln!("writing BENCH_PR_JSON: {e}");
    } else {
        println!("wrote overload_rows ({}) to {:?}", rows.len(), path);
    }
}
