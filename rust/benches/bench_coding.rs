//! Encode/decode hot-path benchmarks: the host-side cost ApproxIFER adds
//! on top of a replication system (paper Fig. 4 — "only an encoder and a
//! decoder are added"). Targets (DESIGN.md §8): encode+decode ≪ model
//! execution at K=12, N+1=31, 32×32×3 payloads.

use approxifer::coding::{ApproxIferCode, BlockPool, CodeParams, GroupBlock};
use approxifer::util::bench::{bench, black_box, group};
use approxifer::util::rng::Rng;

fn payloads(k: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..k).map(|_| (0..d).map(|_| rng.f32() - 0.5).collect()).collect()
}

fn main() {
    group("encode: X~ = W.X (blocked GEMM over flat blocks, per group)");
    for &(k, s, e) in &[(8usize, 1usize, 0usize), (12, 1, 0), (12, 0, 2), (12, 1, 3)] {
        for &d in &[784usize, 3072] {
            let code = ApproxIferCode::new(CodeParams::new(k, s, e));
            let qs = payloads(k, d, 1);
            let qrefs: Vec<&[f32]> = qs.iter().map(|q| &q[..]).collect();
            let queries = GroupBlock::from_rows(&qrefs);
            let pool = BlockPool::new();
            bench(&format!("encode_k{k}_s{s}_e{e}_d{d}"), || {
                // Steady-state shape: take a recycled block, encode,
                // freeze, retire (the drop recycles it for the next iter).
                let mut out = pool.take(code.params().num_workers(), d);
                code.encode_block(black_box(&queries), &mut out);
                black_box(out.freeze());
            });
        }
    }

    group("decode: Y^ = D.Y~ (GEMM into recycled block, per group, C=10 logits)");
    for &(k, s, e) in &[(8usize, 1usize, 0usize), (12, 1, 0), (12, 0, 2)] {
        let params = CodeParams::new(k, s, e);
        let code = ApproxIferCode::new(params);
        let mut rng = Rng::new(2);
        let m = params.decode_set_size().min(params.num_workers());
        let avail = rng.subset(params.num_workers(), m);
        let preds = payloads(m, 10, 3);
        let prefs: Vec<&[f32]> = preds.iter().map(|p| &p[..]).collect();
        let pool = BlockPool::new();
        // Warm the decode-matrix cache: steady-state serving reuses it.
        let _ = code.decode_block(&avail, &prefs, &pool);
        bench(&format!("decode_k{k}_s{s}_e{e}_cached"), || {
            black_box(code.decode_block(black_box(&avail), &prefs, &pool));
        });
    }

    group("decode matrix construction (cache miss path)");
    for &(k, s) in &[(8usize, 1usize), (12, 1)] {
        let params = CodeParams::new(k, s, 0);
        let mut rng = Rng::new(4);
        // Pre-generate distinct availability sets to defeat the cache.
        let sets: Vec<Vec<usize>> =
            (0..1024).map(|_| rng.subset(params.num_workers(), k)).collect();
        let mut i = 0;
        bench(&format!("decode_matrix_miss_k{k}_s{s}"), || {
            // Fresh code object every call would measure allocation; instead
            // rotate sets and accept ~k/1024 cache hits.
            let code = ApproxIferCode::new(params);
            black_box(code.decode_matrix(&sets[i % sets.len()]));
            i += 1;
        });
    }

    group("encoder matrix construction (per (K,S,E), startup cost)");
    for &(k, s, e) in &[(8usize, 1usize, 0usize), (12, 0, 3)] {
        bench(&format!("code_new_k{k}_s{s}_e{e}"), || {
            black_box(ApproxIferCode::new(CodeParams::new(k, s, e)));
        });
    }
}
