//! Error-locator benchmarks (Algorithms 1 and 2) plus the
//! pinned-vs-homogeneous ablation DESIGN.md §7 calls out: the pinned
//! (QR least-squares) variant is the production path; the homogeneous
//! (Jacobi-SVD smallest-singular-vector) variant is the paper's pure
//! Algorithm 1 form.

use approxifer::coding::chebyshev;
use approxifer::coding::locator::{locate, poly_eval, LocatorMethod};
use approxifer::coding::vote::locate_by_vote;
use approxifer::coding::CodeParams;
use approxifer::util::bench::{bench, black_box, group};
use approxifer::util::rng::Rng;

/// Build one corrupted evaluation set for (K, E).
fn case(k: usize, e: usize, sigma: f64, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let params = CodeParams::new(k, 0, e);
    let xs = chebyshev::second_kind(params.n());
    let mut rng = Rng::new(seed);
    let p: Vec<f64> = (0..k).map(|_| rng.range_f64(-2.0, 2.0)).collect();
    let mut ys: Vec<f64> = xs.iter().map(|&x| poly_eval(&p, x)).collect();
    for i in rng.subset(xs.len(), e) {
        ys[i] += rng.normal(0.0, sigma);
    }
    (xs, ys)
}

fn main() {
    group("Algorithm 1 scalar locator (per class coordinate)");
    for &(k, e) in &[(8usize, 2usize), (12, 2), (12, 3)] {
        let (xs, ys) = case(k, e, 10.0, 11);
        bench(&format!("locate_pinned_k{k}_e{e}"), || {
            black_box(locate(&xs, &ys, k, e, LocatorMethod::Pinned).unwrap());
        });
    }

    group("ablation: pinned QR vs homogeneous SVD (K=12, E=2)");
    let (xs, ys) = case(12, 2, 10.0, 13);
    bench("locate_pinned_k12_e2_ablation", || {
        black_box(locate(&xs, &ys, 12, 2, LocatorMethod::Pinned).unwrap());
    });
    bench("locate_homogeneous_k12_e2_ablation", || {
        black_box(locate(&xs, &ys, 12, 2, LocatorMethod::Homogeneous).unwrap());
    });

    group("Algorithm 2 vote (C classes x Algorithm 1)");
    for &(k, e, c) in &[(12usize, 2usize, 10usize), (12, 3, 10), (8, 2, 100)] {
        let params = CodeParams::new(k, 0, e);
        let xs = chebyshev::second_kind(params.n());
        let mut rng = Rng::new(17);
        let m = xs.len();
        let mut preds: Vec<Vec<f32>> = vec![vec![0.0; c]; m];
        for class in 0..c {
            let coeffs: Vec<f64> = (0..4).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            for (i, &x) in xs.iter().enumerate() {
                preds[i][class] = poly_eval(&coeffs, x) as f32;
            }
        }
        for i in rng.subset(m, e) {
            for v in preds[i].iter_mut() {
                *v += rng.normal(0.0, 10.0) as f32;
            }
        }
        let refs: Vec<&[f32]> = preds.iter().map(|p| &p[..]).collect();
        bench(&format!("vote_k{k}_e{e}_c{c}"), || {
            black_box(locate_by_vote(&xs, &refs, k, e, LocatorMethod::Pinned).unwrap());
        });
    }
}
