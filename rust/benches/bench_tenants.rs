//! Multi-tenant fairness bench: two tenants with different schemes and
//! weights share one worker fleet through the [`TenantRegistry`]'s
//! weighted-round-robin dispatch scheduler. Emits `tenant_rows` into
//! `BENCH_PR_JSON` (spliced into the existing artifact when present) so
//! per-tenant goodput, tail latency and the accounting invariant are a
//! tracked regression surface.
//!
//! Two scenarios per run:
//! * `honest` — both tenants closed-loop at their natural rate.
//! * `byz-neighbor` — tenant alpha's groups carry a Byzantine fault plan
//!   (worker 0 corrupts every reply) while beta stays honest. The
//!   fairness property under test: beta still serves **everything**, and
//!   its tail stays bounded, because alpha's in-flight budget caps how
//!   much of the shared fleet its recovery ladder can hold.
//!
//! Every row re-asserts the per-tenant accounting invariant
//! `received == served + degraded + shed + rejected + failed`, and the
//! registry asserts it globally — CI runs this in quick mode as a hard
//! gate, not just a perf printout.

use std::sync::Arc;
use std::time::{Duration, Instant};

use approxifer::coding::CodeParams;
use approxifer::coordinator::{
    Accounting, FaultPlan, Strategy, TenantRegistry, TenantSpec, VerifyPolicy,
};
use approxifer::harness::overload::ClassLatency;
use approxifer::util::bench::quick_mode;
use approxifer::workers::{
    ByzantineMode, InferenceEngine, LinearMockEngine, WorkerPool, WorkerSpec,
};

const D: usize = 16;

fn query(i: usize) -> Vec<f32> {
    (0..D).map(|t| ((i as f32) * 0.13 + (t as f32) * 0.029).sin()).collect()
}

/// One per-tenant result row for a scenario.
struct TenantRow {
    scenario: &'static str,
    tenant: String,
    scheme: String,
    weight: u64,
    budget: usize,
    grants: u64,
    acc: Accounting,
    latency: ClassLatency,
}

impl TenantRow {
    fn line(&self) -> String {
        format!(
            "{:<12} {:<6} {:<24} weight={} budget={} grants={:>5} \
             served={} degraded={} shed={} rejected={} failed={} \
             p50={:.2}ms p99={:.2}ms",
            self.scenario,
            self.tenant,
            self.scheme,
            self.weight,
            self.budget,
            self.grants,
            self.acc.served,
            self.acc.degraded,
            self.acc.shed,
            self.acc.rejected,
            self.acc.failed,
            self.latency.p50_ms,
            self.latency.p99_ms,
        )
    }

    fn json(&self) -> String {
        format!(
            "{{\"scenario\": \"{}\", \"tenant\": \"{}\", \"scheme\": \"{}\", \
             \"weight\": {}, \"budget\": {}, \"grants\": {}, \
             \"received\": {}, \"served\": {}, \"degraded\": {}, \"shed\": {}, \
             \"rejected\": {}, \"failed\": {}, \"latency\": {}}}",
            self.scenario,
            self.tenant,
            self.scheme,
            self.weight,
            self.budget,
            self.grants,
            self.acc.received,
            self.acc.served,
            self.acc.degraded,
            self.acc.shed,
            self.acc.rejected,
            self.acc.failed,
            self.latency.json(),
        )
    }
}

fn scheme_label(spec: &TenantSpec) -> String {
    format!(
        "approxifer(K={},S={},E={})",
        spec.params.k, spec.params.s, spec.params.e
    )
}

/// Run one two-tenant scenario and return a row per tenant. `byz` turns
/// on alpha's Byzantine fault plan; beta is always honest.
fn run_scenario(scenario: &'static str, byz: bool, groups: usize) -> Vec<TenantRow> {
    // alpha (2,1,1) needs 7 workers and runs verified (it has a Byzantine
    // budget to spend); beta (4,1,0) needs 5. One pool serves both, with
    // each worker holding both tenants' engines.
    let engines: Vec<Arc<dyn InferenceEngine>> =
        vec![Arc::new(LinearMockEngine::new(D, 4)), Arc::new(LinearMockEngine::new(D, 8))];
    let pool =
        WorkerPool::spawn_multi(engines, &vec![WorkerSpec::default(); 7], 0xBE5C, None);
    let mut spec_a = TenantSpec {
        name: "alpha".into(),
        strategy: Strategy::ApproxIfer,
        params: CodeParams::new(2, 1, 1),
        verify: VerifyPolicy::on(0.4),
        weight: 3,
        budget: 2,
        batch_deadline: Duration::from_millis(2),
        ..TenantSpec::default()
    };
    spec_a.engine = format!("mock:{D}:4");
    let mut spec_b = TenantSpec {
        name: "beta".into(),
        strategy: Strategy::ApproxIfer,
        params: CodeParams::new(4, 1, 0),
        weight: 1,
        budget: 2,
        batch_deadline: Duration::from_millis(2),
        ..TenantSpec::default()
    };
    spec_b.engine = format!("mock:{D}:8");
    let specs = vec![spec_a, spec_b];
    let labels: Vec<String> = specs.iter().map(scheme_label).collect();
    let registry = TenantRegistry::spawn_with(Box::new(pool), specs, 3, |i, b| {
        if byz && i == 0 {
            b.fault_hook(Arc::new(|_g| FaultPlan {
                byzantine: vec![0],
                byz_mode: Some(ByzantineMode::GaussianNoise { sigma: 10.0 }),
                ..FaultPlan::none()
            }))
        } else {
            b
        }
    })
    .expect("tenant registry spawns");

    // One closed-loop driver thread per tenant, measuring per-query
    // latency from submit to answer.
    let drivers: Vec<_> = (0..registry.tenants().len())
        .map(|i| {
            let svc = registry.tenants()[i].service.clone();
            let k = registry.tenants()[i].spec.params.k;
            std::thread::spawn(move || {
                let mut lat_s: Vec<f64> = Vec::with_capacity(groups * k);
                for g in 0..groups {
                    let handles: Vec<_> =
                        (0..k).map(|j| (Instant::now(), svc.submit(query(g * k + j)))).collect();
                    for (t0, h) in handles {
                        if h.wait_timeout(Duration::from_secs(60)).is_ok() {
                            lat_s.push(t0.elapsed().as_secs_f64());
                        }
                    }
                }
                lat_s
            })
        })
        .collect();
    let latencies: Vec<Vec<f64>> =
        drivers.into_iter().map(|d| d.join().expect("tenant driver")).collect();

    registry.assert_balanced().expect("per-tenant + global accounting");
    let grants = registry.scheduler().grants();
    let rows: Vec<TenantRow> = (0..registry.tenants().len())
        .map(|i| {
            let t = &registry.tenants()[i];
            TenantRow {
                scenario,
                tenant: t.spec.name.clone(),
                scheme: labels[i].clone(),
                weight: t.spec.weight,
                budget: t.spec.budget,
                grants: grants[i],
                acc: registry.accounting(i),
                latency: ClassLatency::of(latencies[i].clone()),
            }
        })
        .collect();

    // The isolation property in numbers: the honest tenant serves its
    // whole workload whatever its neighbor is doing.
    let beta = &rows[1];
    assert_eq!(
        beta.acc.served,
        (groups * 4) as u64,
        "honest beta must serve everything in scenario {scenario}"
    );
    for r in &rows {
        assert!(r.acc.balanced(), "unbalanced tenant row: {}", r.line());
        assert!(r.grants > 0, "tenant {} never dispatched", r.tenant);
    }
    registry.shutdown();
    rows
}

fn main() {
    let quick = quick_mode();
    let groups = if quick { 40 } else { 250 };

    println!("== multi-tenant fairness: two schemes, one fleet, WRR dispatch ==");
    println!("(groups/tenant/scenario: {groups}; weights alpha:beta = 3:1; capacity 3)");

    let mut rows = run_scenario("honest", false, groups);
    rows.extend(run_scenario("byz-neighbor", true, groups));
    for r in &rows {
        println!("{}", r.line());
    }
    println!(
        "\n{} rows, per-tenant and global accounting invariants hold on every scenario",
        rows.len()
    );

    if let Some(path) = std::env::var_os("BENCH_PR_JSON") {
        write_json(&path, &rows);
    }
}

/// Append `tenant_rows` to the `BENCH_PR_JSON` artifact: spliced into the
/// existing object when another bench already wrote it (replacing any
/// previous `tenant_rows` block on a re-run), standalone otherwise.
fn write_json(path: &std::ffi::OsStr, rows: &[TenantRow]) {
    let mut body = String::from("  \"tenant_rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        body.push_str(&format!(
            "    {}{}\n",
            r.json(),
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    body.push_str("  ]\n");
    let out = match std::fs::read_to_string(path) {
        Ok(existing) => {
            let existing = match existing.find(",\n  \"tenant_rows\"") {
                Some(pos) => format!("{}\n}}\n", &existing[..pos]),
                None => existing,
            };
            let trimmed = existing.trim_end();
            match trimmed.strip_suffix('}') {
                Some(head) => format!("{},\n{body}}}\n", head.trim_end()),
                // Not an object we understand — don't clobber it.
                None => {
                    eprintln!("BENCH_PR_JSON exists but is not a JSON object; leaving it");
                    return;
                }
            }
        }
        Err(_) => format!("{{\n  \"bench\": \"bench_tenants\",\n{body}}}\n"),
    };
    if let Err(e) = std::fs::write(path, out) {
        eprintln!("writing BENCH_PR_JSON: {e}");
    } else {
        println!("wrote tenant_rows ({}) to {:?}", rows.len(), path);
    }
}
