//! End-to-end group-latency benchmarks: the coded pipeline vs replication
//! vs no-redundancy under controlled worker tails (the latency side of the
//! paper's motivation; regenerable table `latency` in the harness). Uses
//! the DelayMockEngine so model cost is controlled exactly and the bench
//! isolates coordination overhead + tail behaviour.

use std::sync::Arc;
use std::time::Duration;

use approxifer::coding::replication::ReplicationParams;
use approxifer::coding::CodeParams;
use approxifer::coordinator::{FaultPlan, GroupPipeline, ReplicationPipeline};
use approxifer::metrics::ServingMetrics;
use approxifer::util::bench::{bench_cfg, black_box, group, BenchConfig};
use approxifer::workers::{
    DelayMockEngine, InferenceEngine, LatencyModel, WorkerPool, WorkerSpec,
};

fn queries(k: usize, d: usize) -> Vec<Vec<f32>> {
    (0..k)
        .map(|j| (0..d).map(|t| ((j as f32) * 0.29 + (t as f32) * 0.011).sin()).collect())
        .collect()
}

fn cfg() -> BenchConfig {
    BenchConfig {
        warmup: Duration::from_millis(200),
        min_time: Duration::from_millis(1500),
        min_iters: 30,
        max_iters: 2000,
    }
}

fn main() {
    let (k, d, c) = (8usize, 128usize, 10usize);
    let compute = Duration::from_micros(200);
    let tail = LatencyModel::Exponential { mean_ms: 2.0 };

    group("group latency: coordination + tail (exp 2ms tail, 0.2ms compute)");
    {
        let engine: Arc<dyn InferenceEngine> = Arc::new(DelayMockEngine::new(d, c, compute));
        let params = CodeParams::new(k, 1, 0);
        let specs = vec![WorkerSpec::new(tail); params.num_workers()];
        let pool = WorkerPool::spawn(engine, &specs, 1);
        let mut pipe = GroupPipeline::new(params);
        let metrics = ServingMetrics::new();
        let qs = queries(k, d);
        let qrefs: Vec<&[f32]> = qs.iter().map(|q| &q[..]).collect();
        bench_cfg("approxifer_group_k8_s1_exp", cfg(), || {
            black_box(pipe.infer_group(&pool, &qrefs, &FaultPlan::none(), &metrics).unwrap());
        });
        pool.shutdown();
    }
    {
        let engine: Arc<dyn InferenceEngine> = Arc::new(DelayMockEngine::new(d, c, compute));
        let params = ReplicationParams::new(k, 1, 0);
        let specs = vec![WorkerSpec::new(tail); params.num_workers()];
        let pool = WorkerPool::spawn(engine, &specs, 2);
        let mut pipe = ReplicationPipeline::new(params);
        let metrics = ServingMetrics::new();
        let qs = queries(k, d);
        let qrefs: Vec<&[f32]> = qs.iter().map(|q| &q[..]).collect();
        bench_cfg("replication_group_k8_s1_exp", cfg(), || {
            black_box(pipe.infer_group(&pool, &qrefs, &FaultPlan::none(), &metrics).unwrap());
        });
        pool.shutdown();
    }
    {
        // No redundancy: replication with 1 copy (wait for all).
        let engine: Arc<dyn InferenceEngine> = Arc::new(DelayMockEngine::new(d, c, compute));
        let params = ReplicationParams::new(k, 0, 0);
        let specs = vec![WorkerSpec::new(tail); params.num_workers()];
        let pool = WorkerPool::spawn(engine, &specs, 3);
        let mut pipe = ReplicationPipeline::new(params);
        let metrics = ServingMetrics::new();
        let qs = queries(k, d);
        let qrefs: Vec<&[f32]> = qs.iter().map(|q| &q[..]).collect();
        bench_cfg("no_redundancy_group_k8_exp", cfg(), || {
            black_box(pipe.infer_group(&pool, &qrefs, &FaultPlan::none(), &metrics).unwrap());
        });
        pool.shutdown();
    }

    group("coordination floor: zero tail, zero compute (pure overhead)");
    {
        let engine: Arc<dyn InferenceEngine> =
            Arc::new(DelayMockEngine::new(d, c, Duration::ZERO));
        let params = CodeParams::new(k, 1, 0);
        let pool = WorkerPool::spawn(
            engine,
            &vec![WorkerSpec::new(LatencyModel::None); params.num_workers()],
            4,
        );
        let mut pipe = GroupPipeline::new(params);
        let metrics = ServingMetrics::new();
        let qs = queries(k, d);
        let qrefs: Vec<&[f32]> = qs.iter().map(|q| &q[..]).collect();
        bench_cfg("approxifer_group_floor_k8_s1", cfg(), || {
            black_box(pipe.infer_group(&pool, &qrefs, &FaultPlan::none(), &metrics).unwrap());
        });
        pool.shutdown();
    }

    group("byzantine pipeline: locate+vote on the path (K=12, E=2)");
    {
        let engine: Arc<dyn InferenceEngine> =
            Arc::new(DelayMockEngine::new(d, c, Duration::ZERO));
        let params = CodeParams::new(12, 0, 2);
        let pool = WorkerPool::spawn(
            engine,
            &vec![WorkerSpec::new(LatencyModel::None); params.num_workers()],
            5,
        );
        let mut pipe = GroupPipeline::new(params);
        let metrics = ServingMetrics::new();
        let qs = queries(12, d);
        let qrefs: Vec<&[f32]> = qs.iter().map(|q| &q[..]).collect();
        let plan = FaultPlan {
            byzantine: vec![3, 17],
            byz_mode: Some(approxifer::workers::ByzantineMode::GaussianNoise { sigma: 10.0 }),
            ..FaultPlan::none()
        };
        bench_cfg("approxifer_group_k12_e2_byz", cfg(), || {
            black_box(pipe.infer_group(&pool, &qrefs, &plan, &metrics).unwrap());
        });
        pool.shutdown();
    }
}
