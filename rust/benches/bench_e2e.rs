//! End-to-end group-latency benchmarks: the coded scheme vs replication
//! vs no-redundancy under controlled worker tails (the latency side of the
//! paper's motivation; regenerable table `latency` in the harness). Every
//! strategy runs through the **same** scheme-agnostic online `Service`
//! with the DelayMockEngine, so model cost is controlled exactly and the
//! bench isolates coordination overhead + tail behaviour.

use std::sync::Arc;
use std::time::Duration;

use approxifer::coding::{
    ApproxIferCode, CodeParams, Replication, ServingScheme, Uncoded, VerifyPolicy,
};
use approxifer::coordinator::{FaultPlan, Service};
use approxifer::util::bench::{bench_cfg, black_box, group, BenchConfig};
use approxifer::workers::{ByzantineMode, DelayMockEngine, InferenceEngine, LatencyModel};

fn queries(k: usize, d: usize) -> Vec<Vec<f32>> {
    (0..k)
        .map(|j| (0..d).map(|t| ((j as f32) * 0.29 + (t as f32) * 0.011).sin()).collect())
        .collect()
}

fn cfg() -> BenchConfig {
    BenchConfig {
        warmup: Duration::from_millis(200),
        min_time: Duration::from_millis(1500),
        min_iters: 30,
        max_iters: 2000,
    }
}

/// One closed-loop group through a service: submit K queries, wait for all.
fn one_group(svc: &Service, qs: &[Vec<f32>]) {
    let handles: Vec<_> = qs.iter().map(|q| svc.submit(q.clone())).collect();
    for h in handles {
        black_box(h.wait().unwrap());
    }
}

fn service(
    scheme: Arc<dyn ServingScheme>,
    compute: Duration,
    tail: LatencyModel,
    seed: u64,
) -> Service {
    let (d, c) = (128usize, 10usize);
    let engine: Arc<dyn InferenceEngine> = Arc::new(DelayMockEngine::new(d, c, compute));
    Service::builder(scheme)
        .engine(engine)
        .worker_latency(tail)
        .flush_after(Duration::from_millis(1))
        .seed(seed)
        .spawn()
        .unwrap()
}

fn main() {
    let (k, d) = (8usize, 128usize);
    let compute = Duration::from_micros(200);
    let tail = LatencyModel::Exponential { mean_ms: 2.0 };
    let qs = queries(k, d);

    group("group latency: coordination + tail (exp 2ms tail, 0.2ms compute)");
    {
        let scheme = Arc::new(ApproxIferCode::new(CodeParams::new(k, 1, 0)));
        let svc = service(scheme, compute, tail, 1);
        bench_cfg("approxifer_group_k8_s1_exp", cfg(), || one_group(&svc, &qs));
        svc.shutdown();
    }
    {
        let scheme = Arc::new(Replication::new(k, 1, 0));
        let svc = service(scheme, compute, tail, 2);
        bench_cfg("replication_group_k8_s1_exp", cfg(), || one_group(&svc, &qs));
        svc.shutdown();
    }
    {
        let scheme = Arc::new(Uncoded::new(k));
        let svc = service(scheme, compute, tail, 3);
        bench_cfg("no_redundancy_group_k8_exp", cfg(), || one_group(&svc, &qs));
        svc.shutdown();
    }

    group("coordination floor: zero tail, zero compute (pure overhead)");
    {
        let scheme = Arc::new(ApproxIferCode::new(CodeParams::new(k, 1, 0)));
        let svc = service(scheme, Duration::ZERO, LatencyModel::None, 4);
        bench_cfg("approxifer_group_floor_k8_s1", cfg(), || one_group(&svc, &qs));
        svc.shutdown();
    }

    group("slo hedge: straggler-stalled group served at the hedge deadline (K=4 S=1 E=1)");
    {
        // Two forced 200ms stragglers stall the full 10-of-11 quota; the
        // 10ms SLO hedge decodes from the 9 fast replies instead, so the
        // measured group latency sits at ~the hedge deadline, not the
        // straggler tail.
        let qs4 = queries(4, d);
        let scheme = Arc::new(ApproxIferCode::new(CodeParams::new(4, 1, 1)));
        let engine: Arc<dyn InferenceEngine> =
            Arc::new(DelayMockEngine::new(d, 10, Duration::ZERO));
        let svc = Service::builder(scheme)
            .engine(engine)
            .flush_after(Duration::from_millis(1))
            .seed(6)
            .slo(Duration::from_millis(10))
            .group_timeout(Duration::from_secs(5))
            // Required whenever an SLO coexists with a Byzantine budget
            // (the hedge leans on the verification ladder).
            .verify(VerifyPolicy::on(0.4))
            .fault_hook(Arc::new(|_group| FaultPlan {
                stragglers: vec![0, 1],
                straggler_delay: Duration::from_millis(200),
                ..FaultPlan::none()
            }))
            .spawn()
            .unwrap();
        bench_cfg("approxifer_group_k4_s1_e1_hedged", cfg(), || one_group(&svc, &qs4));
        svc.shutdown();
    }

    group("byzantine pipeline: locate+vote on the path (K=12, E=2)");
    {
        let qs12 = queries(12, d);
        let scheme = Arc::new(ApproxIferCode::new(CodeParams::new(12, 0, 2)));
        let engine: Arc<dyn InferenceEngine> =
            Arc::new(DelayMockEngine::new(d, 10, Duration::ZERO));
        let svc = Service::builder(scheme)
            .engine(engine)
            .flush_after(Duration::from_millis(1))
            .seed(5)
            .fault_hook(Arc::new(|_group| FaultPlan {
                byzantine: vec![3, 17],
                byz_mode: Some(ByzantineMode::GaussianNoise { sigma: 10.0 }),
                ..FaultPlan::none()
            }))
            .spawn()
            .unwrap();
        bench_cfg("approxifer_group_k12_e2_byz", cfg(), || one_group(&svc, &qs12));
        svc.shutdown();
    }
}
