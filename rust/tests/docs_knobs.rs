//! Documentation drift gates: the operator's handbook must document every
//! config knob the schema parses (and nothing else), and the entry-point
//! docs must link to it.

use std::collections::BTreeSet;

use approxifer::config::KNOWN_KEYS;

const OPERATIONS: &str =
    include_str!(concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/OPERATIONS.md"));
const README: &str = include_str!(concat!(env!("CARGO_MANIFEST_DIR"), "/../README.md"));
const ARCHITECTURE: &str =
    include_str!(concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/ARCHITECTURE.md"));

/// Knob-table rows in OPERATIONS.md look like `| `section.key` | ... |`;
/// the first backticked cell is the key.
fn documented_knobs() -> BTreeSet<String> {
    let mut keys = BTreeSet::new();
    for line in OPERATIONS.lines() {
        let Some(rest) = line.trim_start().strip_prefix("| `") else { continue };
        let Some(end) = rest.find('`') else { continue };
        let key = &rest[..end];
        // Only dotted section.key cells are knobs; other tables may lead
        // with backticked words (metric names, CLI flags).
        if key.contains('.') && !key.contains(' ') {
            keys.insert(key.to_string());
        }
    }
    keys
}

#[test]
fn operations_handbook_documents_every_config_knob() {
    let documented = documented_knobs();
    let known: BTreeSet<String> = KNOWN_KEYS.iter().map(|k| k.to_string()).collect();
    let missing: Vec<_> = known.difference(&documented).collect();
    let stale: Vec<_> = documented.difference(&known).collect();
    assert!(
        missing.is_empty(),
        "knobs parsed by the config schema but absent from docs/OPERATIONS.md: {missing:?}"
    );
    assert!(
        stale.is_empty(),
        "knobs documented in docs/OPERATIONS.md but unknown to the config schema: {stale:?}"
    );
}

#[test]
fn readme_and_architecture_link_to_the_handbook() {
    assert!(
        README.contains("docs/OPERATIONS.md"),
        "README.md must point operators at docs/OPERATIONS.md"
    );
    assert!(
        ARCHITECTURE.contains("OPERATIONS.md"),
        "docs/ARCHITECTURE.md must link to the operator's handbook"
    );
}

#[test]
fn handbook_covers_the_overload_outcome_vocabulary() {
    for word in ["served", "degraded", "shed", "rejected", "failed"] {
        assert!(
            OPERATIONS.contains(word),
            "docs/OPERATIONS.md must define the '{word}' outcome class"
        );
    }
    for section in ["runbook", "Runbook"] {
        if OPERATIONS.contains(section) {
            return;
        }
    }
    panic!("docs/OPERATIONS.md must contain a runbook section");
}
