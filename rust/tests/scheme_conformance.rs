//! Scheme-conformance property suite: every [`ServingScheme`]
//! implementation — ApproxIFER, NeRCC, replication, ParM-proxy, uncoded —
//! runs the same encode → fault → collect → decode matrix through the
//! unified `Service`, swept over `(K, S, E)` cells with ragged payload
//! widths, under five fault families (honest, `crash:S@0`, slow-tail,
//! `byz-random`, `byz-collude`) at fixed seeds. Each cell asserts:
//!
//! * **Tolerance envelope** — in-envelope faults are absorbed within the
//!   scheme's documented accuracy budget (exact for replication / ParM on
//!   an affine engine / uncoded, calibrated regression error for NeRCC,
//!   the Berrut approximation envelope for ApproxIFER); out-of-envelope
//!   faults degrade or fail cleanly, never hang.
//! * **Exact outcome accounting** — once quiescent,
//!   `received == served + degraded + shed + rejected + failed` and
//!   `groups_decoded + groups_failed == groups_dispatched − redispatches`.
//! * **Bit-identical seeded replay** — any cell whose collected reply set
//!   is scheduling-free (every slot's live worker count equals the collect
//!   quota) must reproduce byte-identical predictions across runs.
//! * **NeRCC vs ApproxIFER delta** — NeRCC's worst deviation stays within
//!   `+0.01` of ApproxIFER's on the same cell (the successor scheme never
//!   trades accuracy for its leaner `K+S+2E` fleet).
//!
//! Plus cross-cutting properties: `(S, E)` reconfiguration round-trips to
//! a bit-identical encoder and collect policy, and every scheme satisfies
//! `overhead() == num_workers()/K` with a satisfiable collection quota.

use std::sync::Arc;
use std::time::{Duration, Instant};

use approxifer::coding::{
    ApproxIferCode, BlockBuf, CodeParams, CollectPolicy, GroupBlock, NerccCode, NerccParams,
    ParmProxy, Replication, RowView, ServingScheme, Uncoded, VerifyPolicy,
};
use approxifer::coordinator::{Accounting, Service};
use approxifer::sim::faults::FaultProfile;
use approxifer::workers::{InferenceEngine, LinearMockEngine};

const SEED: u64 = 0x5EED;
const GROUPS: usize = 2;

/// The `(K)` × `(S, E)` sweep. Kept CI-small: two group sizes against
/// every straggler/Byzantine budget combination the schemes support.
const KS: [usize; 2] = [2, 4];
const SE: [(usize, usize); 6] = [(0, 0), (1, 0), (2, 0), (0, 1), (1, 1), (2, 1)];

/// Ragged payload widths: every cell gets its own `(d, c)` so the sweep
/// exercises the block pool and GEMM paths at varied shapes instead of one
/// fixed width.
fn cell_dims(k: usize, s: usize, e: usize) -> (usize, usize) {
    (5 + (k + 2 * s + 3 * e) % 4, 3 + (k + s + e) % 3)
}

fn payload(j: usize, d: usize) -> Vec<f32> {
    (0..d).map(|t| ((j as f32) * 0.21 + (t as f32) * 0.019).sin()).collect()
}

/// Scheme builders for one `(K, S, E)` cell; `None` when the scheme does
/// not support the cell (ParM is hardwired to `(·, 1, 0)`, uncoded to
/// `(·, 0, 0)`). The ApproxIFER → NeRCC order matters: the matrix tests
/// compare NeRCC's deviation against ApproxIFER's on the same cell.
type Builder = fn(usize, usize, usize) -> Option<Arc<dyn ServingScheme>>;

fn builders() -> Vec<(&'static str, Builder)> {
    vec![
        ("approxifer", |k, s, e| {
            Some(Arc::new(ApproxIferCode::new(CodeParams::new(k, s, e))) as Arc<dyn ServingScheme>)
        }),
        ("nercc", |k, s, e| {
            Some(Arc::new(NerccCode::new(NerccParams::new(k, s, e))) as Arc<dyn ServingScheme>)
        }),
        ("replication", |k, s, e| {
            Some(Arc::new(Replication::new(k, s, e)) as Arc<dyn ServingScheme>)
        }),
        ("parm-proxy", |k, s, e| {
            (s == 1 && e == 0).then(|| Arc::new(ParmProxy::new(k)) as Arc<dyn ServingScheme>)
        }),
        ("uncoded", |k, s, e| {
            (s == 0 && e == 0).then(|| Arc::new(Uncoded::new(k)) as Arc<dyn ServingScheme>)
        }),
    ]
}

/// Worst absolute deviation a scheme's served predictions may show against
/// the engine's reference output on an affine mock model.
fn tol(name: &str) -> f32 {
    match name {
        // Berrut rational interpolation is approximate by design; this is
        // the envelope across the whole (K, S, E) sweep, not a sharp bound.
        "approxifer" => 1.0,
        // Calibrated: the ridge decode is ≲ 1e-3 off for K ≤ 8 on an
        // affine engine (worst cell: S=2 one-sided extrapolation).
        "nercc" => 0.05,
        // Replication / ParM (affine ⇒ the parity proxy is exact) /
        // uncoded reproduce the engine up to f32 noise.
        _ => 1e-3,
    }
}

/// Decode-verification residual threshold per scheme: ApproxIFER's
/// re-encode residual carries the Berrut approximation error (grows with
/// K+S), the others sit near numerical noise.
fn verify_tol(name: &str) -> f64 {
    if name == "approxifer" {
        0.8
    } else {
        0.4
    }
}

/// Serve `groups` full K-groups through a freshly built service; returns
/// per-query results (in submission order) and the service for metrics.
fn serve(
    scheme: Arc<dyn ServingScheme>,
    profile: FaultProfile,
    verify: VerifyPolicy,
    groups: usize,
    d: usize,
    c: usize,
    group_timeout: Duration,
) -> (Vec<anyhow::Result<RowView>>, Service, Arc<LinearMockEngine>) {
    let engine = Arc::new(LinearMockEngine::new(d, c));
    let svc = Service::builder(scheme)
        .engine(engine.clone())
        .flush_after(Duration::from_millis(5))
        .verify(verify)
        .seed(SEED)
        .group_timeout(group_timeout)
        .fault_profile(profile)
        .spawn()
        .unwrap();
    let k = svc.scheme().group_size();
    let handles: Vec<_> = (0..groups * k).map(|j| svc.submit(payload(j, d))).collect();
    let results: Vec<anyhow::Result<RowView>> =
        handles.into_iter().map(|h| h.wait_timeout(Duration::from_secs(20))).collect();
    (results, svc, engine)
}

/// Parse `spec` against the scheme's fleet and serve one cell.
fn run_cell(
    scheme: Arc<dyn ServingScheme>,
    spec: &str,
    verify: VerifyPolicy,
    d: usize,
    c: usize,
) -> (Vec<anyhow::Result<RowView>>, Service, Arc<LinearMockEngine>) {
    let profile = FaultProfile::parse(spec, scheme.num_workers(), SEED).unwrap();
    serve(scheme, profile, verify, GROUPS, d, c, Duration::from_secs(20))
}

/// Worst per-class absolute deviation across every served query; panics on
/// any failed query (in-envelope cells must serve everything).
fn max_deviation(
    cell: &str,
    results: &[anyhow::Result<RowView>],
    engine: &LinearMockEngine,
    d: usize,
    c: usize,
) -> f32 {
    let mut worst = 0f32;
    for (j, r) in results.iter().enumerate() {
        let pred = r.as_ref().unwrap_or_else(|e| panic!("{cell}: query {j} failed: {e:#}"));
        let want = engine.infer1(&payload(j, d)).unwrap();
        for t in 0..c {
            worst = worst.max((pred[t] - want[t]).abs());
        }
    }
    worst
}

/// Exact outcome accounting once the cell is quiescent. Counters land
/// just after handle delivery, so poll briefly before declaring a
/// violation.
fn assert_accounting(cell: &str, svc: &Service, groups: u64, queries: u64) {
    let deadline = Instant::now() + Duration::from_secs(2);
    loop {
        let a = Accounting::of(&svc.metrics);
        let redispatches = svc.metrics.redispatches.get();
        let decoded = svc.metrics.groups_decoded.get();
        let failed = svc.metrics.groups_failed.get();
        let dispatched = svc.metrics.groups_dispatched.get();
        let settled = a.received == queries
            && a.balanced()
            && decoded + failed == dispatched - redispatches
            && dispatched - redispatches == groups;
        if settled {
            return;
        }
        if Instant::now() > deadline {
            panic!(
                "{cell}: accounting never settled: {a:?} decoded={decoded} failed={failed} \
                 dispatched={dispatched} redispatches={redispatches} (want {groups} groups, \
                 {queries} queries)"
            );
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// A cell's collected reply set is independent of worker scheduling iff
/// every slot's live (non-crashed) worker count exactly equals the collect
/// quota — then seeded replay must be bit-identical. (Hedged quotas never
/// fire here: hedging requires an SLO and this suite sets none.)
fn scheduling_free(policy: &CollectPolicy, dead: &[usize]) -> bool {
    let slots = policy.num_slots().max(1);
    let mut live = vec![0usize; slots];
    for (w, &slot) in policy.slots.iter().enumerate() {
        if !dead.contains(&w) {
            live[slot] += 1;
        }
    }
    live.iter().all(|&l| l == policy.need)
}

fn unwrapped(results: &[anyhow::Result<RowView>]) -> Vec<RowView> {
    results.iter().map(|r| r.as_ref().unwrap().clone()).collect()
}

/// Re-run a scheduling-free cell and demand byte-identical predictions.
fn assert_replays(
    cell: &str,
    first: &[anyhow::Result<RowView>],
    scheme: Arc<dyn ServingScheme>,
    spec: &str,
    verify: VerifyPolicy,
    d: usize,
    c: usize,
) {
    let (second, svc, _engine) = run_cell(scheme, spec, verify, d, c);
    svc.shutdown();
    assert_eq!(unwrapped(first), unwrapped(&second), "{cell}: replay diverged");
}

/// One in-envelope fault family swept over the whole matrix. `spec_for`
/// yields the profile spec for a cell (`None` skips the cell — e.g. crash
/// cells need S ≥ 1), `dead_for` the worker set that never replies under
/// that profile (for the scheduling-free replay predicate), `replayable`
/// gates the replay assert off entirely for families with timing-dependent
/// collection (slow-tail).
fn sweep_matrix(
    family: &str,
    spec_for: impl Fn(usize, usize) -> Option<String>,
    replayable: bool,
    mut extra: impl FnMut(&str, &str, usize, usize, &Service),
) {
    for &k in &KS {
        for &(s, e) in &SE {
            let Some(spec) = spec_for(s, e) else { continue };
            let (d, c) = cell_dims(k, s, e);
            let mut apx_dev = None;
            for (name, build) in builders() {
                let Some(scheme) = build(k, s, e) else { continue };
                let cell = format!("{name}(K={k},S={s},E={e})/{family}");
                let verify =
                    if e > 0 { VerifyPolicy::on(verify_tol(name)) } else { VerifyPolicy::off() };
                let (results, svc, engine) = run_cell(scheme.clone(), &spec, verify, d, c);
                let dev = max_deviation(&cell, &results, &engine, d, c);
                assert!(dev < tol(name), "{cell}: deviation {dev} exceeds envelope {}", tol(name));
                assert_eq!(svc.metrics.groups_failed.get(), 0, "{cell}: in-envelope group failed");
                assert_accounting(&cell, &svc, GROUPS as u64, (GROUPS * k) as u64);
                extra(&cell, name, s, e, &svc);
                svc.shutdown();
                match name {
                    "approxifer" => apx_dev = Some(dev),
                    "nercc" => {
                        let a = apx_dev.expect("approxifer runs before nercc");
                        assert!(
                            dev <= a + 0.01,
                            "{cell}: nercc deviation {dev} worse than approxifer {a} + 0.01"
                        );
                    }
                    _ => {}
                }
                let profile = FaultProfile::parse(&spec, scheme.num_workers(), SEED).unwrap();
                let dead: Vec<usize> =
                    if spec.starts_with("crash") { profile.faulty() } else { Vec::new() };
                if replayable && scheduling_free(&scheme.collect_policy(), &dead) {
                    assert_replays(&cell, &results, scheme, &spec, verify, d, c);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The (scheme × fault × (K,S,E)) matrix, one test per fault family
// ---------------------------------------------------------------------------

#[test]
fn honest_cells_decode_in_envelope_and_replay() {
    sweep_matrix("honest", |_, _| Some("honest".into()), true, |_, _, _, _, _| {});
}

#[test]
fn crash_cells_absorb_stragglers_and_replay() {
    // crash:S@0 = exactly the straggler budget of seed-chosen workers
    // never answer. Every scheme in the cell advertises
    // stragglers_tolerated >= S, so full-accuracy service is the claim.
    sweep_matrix(
        "crash",
        |s, _| (s >= 1).then(|| format!("crash:{s}@0")),
        true,
        |cell, _, _, _, svc| {
            assert_eq!(svc.metrics.redispatches.get(), 0, "{cell}: crash must not redispatch");
        },
    );
}

#[test]
fn slow_tail_cells_absorb_stragglers() {
    // S seed-chosen workers answer tens of ms late (p=0.8 tail); the
    // fastest-quota collection must ride over them. Replies still arrive,
    // so the collected set is timing-dependent: no replay assert here.
    sweep_matrix("slow", |s, _| (s >= 1).then(|| format!("slow:{s}:1:30:0.8")), false, |_, _, _, _, _| {})
}

#[test]
fn byz_random_cells_locate_or_outvote_the_adversary() {
    sweep_matrix(
        "byz-random",
        |_, e| (e >= 1).then(|| format!("byz-random:{e}:15")),
        true,
        |cell, _, s, _, svc| {
            assert!(
                svc.metrics.corrupt_replies_injected.get() > 0,
                "{cell}: injection never fired"
            );
            if s == 0 {
                // With no straggler slack the adversary is always in the
                // collected set, so it must have been flagged.
                assert!(
                    svc.metrics.byzantine_flagged.get() > 0,
                    "{cell}: adversary never flagged"
                );
            }
        },
    );
}

#[test]
fn byz_collude_cells_locate_or_outvote_the_pact() {
    sweep_matrix(
        "byz-collude",
        |_, e| (e >= 1).then(|| format!("byz-collude:{e}:15")),
        true,
        |cell, _, s, _, svc| {
            assert!(
                svc.metrics.corrupt_replies_injected.get() > 0,
                "{cell}: injection never fired"
            );
            if s == 0 {
                assert!(
                    svc.metrics.byzantine_flagged.get() > 0,
                    "{cell}: colluders never flagged"
                );
            }
        },
    );
}

// ---------------------------------------------------------------------------
// Reconfiguration round-trip (satellite: adaptive control plane contract)
// ---------------------------------------------------------------------------

#[test]
fn reconfigure_round_trip_restores_a_bit_identical_scheme() {
    // (S, E) → (S', E') → (S, E) must restore the scheme exactly: same
    // fleet, same collect policy, and a bit-identical encoder output — the
    // adaptive controller may bounce a live service between envelopes
    // without accumulating drift.
    let cases: Vec<(Arc<dyn ServingScheme>, (usize, usize))> = vec![
        (Arc::new(ApproxIferCode::new(CodeParams::new(4, 1, 0))), (2, 1)),
        (Arc::new(NerccCode::new(NerccParams::new(4, 1, 0))), (2, 1)),
        (Arc::new(Replication::new(4, 1, 0)), (0, 2)),
    ];
    for (orig, (s2, e2)) in cases {
        let name = orig.name().to_string();
        let (s0, e0) = (orig.stragglers_tolerated(), orig.byzantine_tolerated());
        let up = orig.reconfigure(s2, e2).unwrap();
        assert_eq!(up.group_size(), orig.group_size(), "{name}: K must survive reconfigure");
        assert_eq!((up.stragglers_tolerated(), up.byzantine_tolerated()), (s2, e2), "{name}");
        let back = up.reconfigure(s0, e0).unwrap();
        assert_eq!(back.name(), orig.name());
        assert_eq!(back.num_workers(), orig.num_workers(), "{name}");
        assert_eq!(back.collect_policy(), orig.collect_policy(), "{name}");
        assert_eq!(back.overhead(), orig.overhead(), "{name}");
        // Bit-identical encoder: same queries in, byte-equal coded block
        // out of the original and the round-tripped scheme.
        let (k, d) = (orig.group_size(), 7);
        let rows: Vec<Vec<f32>> = (0..k).map(|j| payload(j, d)).collect();
        let row_refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let queries = GroupBlock::from_rows(&row_refs);
        let mut a = BlockBuf::unpooled(orig.num_workers(), d);
        let mut b = BlockBuf::unpooled(orig.num_workers(), d);
        orig.encode_into(&queries, &mut a);
        back.encode_into(&queries, &mut b);
        assert_eq!(a.as_slice(), b.as_slice(), "{name}: round-tripped encoder diverged");
    }
    // The fixed-envelope schemes refuse, not panic.
    let parm: Arc<dyn ServingScheme> = Arc::new(ParmProxy::new(4));
    assert!(parm.reconfigure(0, 0).is_err());
    let uncoded: Arc<dyn ServingScheme> = Arc::new(Uncoded::new(4));
    assert!(uncoded.reconfigure(1, 0).is_err());
}

// ---------------------------------------------------------------------------
// Overhead identity + collect-quota satisfiability (satellite)
// ---------------------------------------------------------------------------

#[test]
fn overhead_identity_and_collect_quotas_hold_for_every_scheme() {
    for &k in &KS {
        for &(s, e) in &SE {
            for (name, build) in builders() {
                let Some(scheme) = build(k, s, e) else { continue };
                let cell = format!("{name}(K={k},S={s},E={e})");
                let nw = scheme.num_workers();
                let expect = nw as f64 / scheme.group_size() as f64;
                assert!(
                    (scheme.overhead() - expect).abs() < 1e-12,
                    "{cell}: overhead {} != num_workers/K = {expect}",
                    scheme.overhead()
                );
                let p = scheme.collect_policy();
                assert_eq!(p.num_workers(), nw, "{cell}: policy must cover the whole fleet");
                assert!(p.need >= 1, "{cell}: zero-reply quota");
                if let Some(h) = p.hedge_need {
                    assert!(h >= 1 && h < p.need, "{cell}: hedge quota {h} vs need {}", p.need);
                }
                // Quota satisfiability: every slot must have at least
                // `need` workers feeding it, or collection can never
                // complete even on an honest fleet.
                let slots = p.num_slots();
                assert!(slots >= 1, "{cell}: no collection slots");
                let mut per = vec![0usize; slots];
                for &slot in &p.slots {
                    per[slot] += 1;
                }
                for (slot, &cnt) in per.iter().enumerate() {
                    assert!(
                        cnt >= p.need,
                        "{cell}: slot {slot} has {cnt} workers < quota {}",
                        p.need
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Out-of-envelope cells fail cleanly
// ---------------------------------------------------------------------------

#[test]
fn one_crashed_worker_fails_uncoded_cleanly() {
    // Uncoded advertises stragglers_tolerated == 0: with one crashed
    // worker its groups must error out at the collection deadline — a
    // clean, observable failure, not a hang — and the accounting still
    // balances (every query resolves exactly once, as `failed`).
    let scheme: Arc<dyn ServingScheme> = Arc::new(Uncoded::new(4));
    assert_eq!(scheme.stragglers_tolerated(), 0);
    let (d, c) = (8, 6);
    let profile = FaultProfile::parse("crash:1@0", scheme.num_workers(), SEED).unwrap();
    let (results, svc, _engine) =
        serve(scheme, profile, VerifyPolicy::off(), 2, d, c, Duration::from_millis(400));
    for (j, r) in results.iter().enumerate() {
        assert!(r.is_err(), "query {j} should have failed with a crashed worker");
    }
    assert_eq!(svc.metrics.groups_failed.get(), 2);
    assert_eq!(svc.metrics.groups_decoded.get(), 0);
    let acct = Accounting::of(&svc.metrics);
    assert!(acct.balanced(), "failed cell must still balance: {acct:?}");
    assert_eq!(acct.failed, 8);
    svc.shutdown();
}

#[test]
fn byzantine_worker_corrupts_unprotected_schemes_but_service_survives() {
    // Uncoded has no Byzantine tolerance: the adversary's answers go
    // straight through. The envelope claim under test is liveness — every
    // query still resolves — and that the injection actually happened.
    let scheme: Arc<dyn ServingScheme> = Arc::new(Uncoded::new(3));
    assert_eq!(scheme.byzantine_tolerated(), 0);
    let (d, c) = (8, 6);
    let profile = FaultProfile::parse("byz-random:1:15", scheme.num_workers(), SEED).unwrap();
    let (results, svc, _engine) =
        serve(scheme, profile, VerifyPolicy::off(), 3, d, c, Duration::from_secs(20));
    for (j, r) in results.iter().enumerate() {
        assert!(r.is_ok(), "query {j} must still resolve: {:?}", r.as_ref().err());
    }
    assert!(svc.metrics.corrupt_replies_injected.get() > 0, "injection never fired");
    assert_eq!(svc.metrics.groups_failed.get(), 0);
    svc.shutdown();
}
