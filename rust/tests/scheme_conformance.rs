//! Scheme-conformance suite: every [`ServingScheme`] implementation runs
//! the same encode → fault → collect → decode matrix through the unified
//! `Service` — honest, `crash:1@0` and `byz-random` profiles under fixed
//! seeds — and each scheme's documented tolerance envelope
//! (`stragglers_tolerated` / `byzantine_tolerated`) is asserted to hold:
//! in-envelope faults must be absorbed accurately, out-of-envelope faults
//! must degrade or fail cleanly (never hang).

use std::sync::Arc;
use std::time::Duration;

use approxifer::coding::{
    ApproxIferCode, CodeParams, ParmProxy, Replication, RowView, ServingScheme, Uncoded,
    VerifyPolicy,
};
use approxifer::coordinator::Service;
use approxifer::sim::faults::FaultProfile;
use approxifer::workers::{InferenceEngine, LinearMockEngine};

const D: usize = 8;
const C: usize = 6;
const SEED: u64 = 0x5EED;

fn payload(j: usize) -> Vec<f32> {
    (0..D).map(|t| ((j as f32) * 0.21 + (t as f32) * 0.019).sin()).collect()
}

/// The conformance fleet: every scheme, at straggler- and (where
/// supported) Byzantine-tolerant parameters.
fn straggler_schemes() -> Vec<Arc<dyn ServingScheme>> {
    vec![
        Arc::new(ApproxIferCode::new(CodeParams::new(4, 1, 0))),
        Arc::new(Replication::new(4, 1, 0)),
        Arc::new(ParmProxy::new(4)),
    ]
}

fn byzantine_schemes() -> Vec<Arc<dyn ServingScheme>> {
    vec![
        Arc::new(ApproxIferCode::new(CodeParams::new(3, 0, 1))),
        Arc::new(Replication::new(3, 0, 1)),
    ]
}

/// Serve `groups` full K-groups through a freshly built service; returns
/// per-query results (in submission order) and the service for metrics.
fn serve(
    scheme: Arc<dyn ServingScheme>,
    profile: FaultProfile,
    verify: VerifyPolicy,
    groups: usize,
    group_timeout: Duration,
) -> (Vec<anyhow::Result<RowView>>, Service, Arc<LinearMockEngine>) {
    let engine = Arc::new(LinearMockEngine::new(D, C));
    let svc = Service::builder(scheme)
        .engine(engine.clone())
        .flush_after(Duration::from_millis(5))
        .verify(verify)
        .seed(SEED)
        .group_timeout(group_timeout)
        .fault_profile(profile)
        .spawn()
        .unwrap();
    let k = svc.scheme().group_size();
    let handles: Vec<_> = (0..groups * k).map(|j| svc.submit(payload(j))).collect();
    let results: Vec<anyhow::Result<RowView>> =
        handles.into_iter().map(|h| h.wait_timeout(Duration::from_secs(20))).collect();
    (results, svc, engine)
}

/// Max per-class deviation from the engine's reference prediction a scheme
/// is allowed: coded approximation error for ApproxIFER, numerical noise
/// for the exact schemes.
fn tolerance(scheme: &dyn ServingScheme) -> f32 {
    if scheme.name() == "approxifer" {
        if scheme.byzantine_tolerated() > 0 {
            0.6
        } else {
            0.35
        }
    } else {
        1e-3
    }
}

fn assert_accurate(
    name: &str,
    results: &[anyhow::Result<RowView>],
    engine: &LinearMockEngine,
    tol: f32,
) {
    for (j, r) in results.iter().enumerate() {
        let pred = r.as_ref().unwrap_or_else(|e| panic!("{name}: query {j} failed: {e:#}"));
        let want = engine.infer1(&payload(j)).unwrap();
        for t in 0..C {
            assert!(
                (pred[t] - want[t]).abs() < tol,
                "{name}: q{j} c{t}: {} vs {} (tol {tol})",
                pred[t],
                want[t]
            );
        }
    }
}

#[test]
fn honest_fleet_every_scheme_is_accurate() {
    let mut all: Vec<Arc<dyn ServingScheme>> = straggler_schemes();
    all.extend(byzantine_schemes());
    all.push(Arc::new(Uncoded::new(4)));
    for scheme in all {
        let name = scheme.name().to_string();
        let tol = tolerance(scheme.as_ref());
        let nw = scheme.num_workers();
        let verify = if scheme.byzantine_tolerated() > 0 {
            VerifyPolicy::on(0.4)
        } else {
            VerifyPolicy::off()
        };
        let (results, svc, engine) = serve(
            scheme,
            FaultProfile::honest(nw),
            verify,
            3,
            Duration::from_secs(20),
        );
        assert_accurate(&name, &results, &engine, tol);
        assert_eq!(svc.metrics.groups_decoded.get(), 3, "{name}");
        assert_eq!(svc.metrics.groups_failed.get(), 0, "{name}");
        svc.shutdown();
    }
}

#[test]
fn one_crashed_worker_is_absorbed_by_straggler_tolerant_schemes() {
    // crash:1@0 = one seed-chosen worker never answers — a permanent
    // straggler. Every scheme advertising stragglers_tolerated >= 1 must
    // serve every query at full accuracy.
    for scheme in straggler_schemes() {
        let name = scheme.name().to_string();
        assert!(scheme.stragglers_tolerated() >= 1, "{name} not in this matrix");
        let tol = tolerance(scheme.as_ref());
        let profile = FaultProfile::parse("crash:1@0", scheme.num_workers(), SEED).unwrap();
        let (results, svc, engine) =
            serve(scheme, profile, VerifyPolicy::off(), 3, Duration::from_secs(20));
        assert_accurate(&name, &results, &engine, tol);
        assert_eq!(svc.metrics.groups_failed.get(), 0, "{name}");
        svc.shutdown();
    }
}

#[test]
fn one_crashed_worker_fails_uncoded_cleanly() {
    // Uncoded advertises stragglers_tolerated == 0: with one crashed
    // worker its groups must error out at the collection deadline — a
    // clean, observable failure, not a hang.
    let scheme: Arc<dyn ServingScheme> = Arc::new(Uncoded::new(4));
    assert_eq!(scheme.stragglers_tolerated(), 0);
    let profile = FaultProfile::parse("crash:1@0", scheme.num_workers(), SEED).unwrap();
    let (results, svc, _engine) =
        serve(scheme, profile, VerifyPolicy::off(), 2, Duration::from_millis(400));
    for (j, r) in results.iter().enumerate() {
        assert!(r.is_err(), "query {j} should have failed with a crashed worker");
    }
    assert_eq!(svc.metrics.groups_failed.get(), 2);
    assert_eq!(svc.metrics.groups_decoded.get(), 0);
    svc.shutdown();
}

#[test]
fn one_byzantine_worker_is_defeated_by_tolerant_schemes() {
    // byz-random:1:15 = one seed-chosen Gaussian-noise adversary. Schemes
    // with byzantine_tolerated >= 1 must locate/outvote it and stay
    // accurate; verification must confirm the decode.
    for scheme in byzantine_schemes() {
        let name = scheme.name().to_string();
        assert!(scheme.byzantine_tolerated() >= 1, "{name} not in this matrix");
        let tol = tolerance(scheme.as_ref());
        let profile = FaultProfile::parse("byz-random:1:15", scheme.num_workers(), SEED).unwrap();
        let (results, svc, engine) =
            serve(scheme, profile, VerifyPolicy::on(0.4), 3, Duration::from_secs(20));
        assert_accurate(&name, &results, &engine, tol);
        assert!(
            svc.metrics.corrupt_replies_injected.get() > 0,
            "{name}: injection never fired"
        );
        assert!(svc.metrics.byzantine_flagged.get() > 0, "{name}: adversary never flagged");
        assert_eq!(svc.metrics.redispatches.get(), 0, "{name}: in-envelope must not redispatch");
        svc.shutdown();
    }
}

#[test]
fn byzantine_worker_corrupts_unprotected_schemes_but_service_survives() {
    // Uncoded has no Byzantine tolerance: the adversary's answers go
    // straight through. The envelope claim under test is liveness — every
    // query still resolves — and that the injection actually happened.
    let scheme: Arc<dyn ServingScheme> = Arc::new(Uncoded::new(3));
    assert_eq!(scheme.byzantine_tolerated(), 0);
    let profile = FaultProfile::parse("byz-random:1:15", scheme.num_workers(), SEED).unwrap();
    let (results, svc, _engine) =
        serve(scheme, profile, VerifyPolicy::off(), 3, Duration::from_secs(20));
    for (j, r) in results.iter().enumerate() {
        assert!(r.is_ok(), "query {j} must still resolve: {:?}", r.as_ref().err());
    }
    assert!(svc.metrics.corrupt_replies_injected.get() > 0, "injection never fired");
    assert_eq!(svc.metrics.groups_failed.get(), 0);
    svc.shutdown();
}

#[test]
fn crash_scenario_replays_bit_identically_for_every_scheme() {
    // Fixed seed + crash profile → the decode set is scheduling-free for
    // every scheme, so the served predictions must be byte-identical
    // across runs (the determinism contract the fault subsystem
    // guarantees).
    let build: Vec<fn() -> Arc<dyn ServingScheme>> = vec![
        || Arc::new(ApproxIferCode::new(CodeParams::new(4, 1, 0))),
        || Arc::new(Replication::new(4, 1, 0)),
        || Arc::new(ParmProxy::new(4)),
    ];
    for mk in build {
        let run = || {
            let scheme = mk();
            let profile =
                FaultProfile::parse("crash:1@0", scheme.num_workers(), SEED).unwrap();
            let (results, svc, _engine) =
                serve(scheme, profile, VerifyPolicy::off(), 2, Duration::from_secs(20));
            svc.shutdown();
            results.into_iter().map(|r| r.unwrap()).collect::<Vec<_>>()
        };
        let (a, b) = (run(), run());
        assert_eq!(a, b, "replay diverged");
    }
}
