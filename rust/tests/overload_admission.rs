//! Integration tests for the overload subsystem: deadline-aware batching
//! under trickle load, and the admission accounting invariant under a
//! deterministic flash-crowd driven through the open-loop harness.

use std::sync::Arc;
use std::time::{Duration, Instant};

use approxifer::coding::{ApproxIferCode, CodeParams};
use approxifer::coordinator::{AdmissionConfig, Priority, Service, ShedPolicy};
use approxifer::harness::overload::{drive, LoadTrace};
use approxifer::workers::{DelayMockEngine, InferenceEngine, LinearMockEngine};

fn payload(j: usize, d: usize) -> Vec<f32> {
    (0..d).map(|t| ((j as f32) * 0.23 + (t as f32) * 0.013).sin()).collect()
}

/// The acceptance bar for deadline-aware batching: a trickle workload
/// (arrival rate far below K per deadline) completes **every** query within
/// the batching deadline plus group service latency — nothing waits for a
/// full group that will never form.
#[test]
fn trickle_workload_never_waits_for_a_full_group() {
    let deadline = Duration::from_millis(25);
    let engine: Arc<dyn InferenceEngine> = Arc::new(LinearMockEngine::new(8, 3));
    let svc = Service::builder(Arc::new(ApproxIferCode::new(CodeParams::new(4, 1, 0))))
        .engine(engine)
        .batch_deadline(deadline)
        .spawn()
        .unwrap();
    let queries = 6;
    for j in 0..queries {
        let t0 = Instant::now();
        let h = svc.submit(payload(j, 8));
        h.wait_timeout(Duration::from_secs(10)).unwrap();
        let elapsed = t0.elapsed();
        // Generous decode/scheduling slack on CI boxes, but far below the
        // "wait forever for 3 more queries" failure mode this guards.
        assert!(
            elapsed < deadline + Duration::from_secs(2),
            "query {j} took {elapsed:?} — stalled past the batching deadline"
        );
        // Spacing: the next query arrives after this one's group closed,
        // so every group is a singleton deadline flush.
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(svc.metrics.queries_served.get(), queries as u64);
    assert_eq!(svc.metrics.deadline_flushes.get(), queries as u64);
    assert_eq!(svc.metrics.pad_slots.get(), (queries * 3) as u64);
    svc.shutdown();
}

/// The accounting invariant under a deterministic flash-crowd: arrivals
/// far outrun a deliberately slow pipeline, the bounded queue sheds and
/// rejects, and submitted == served + degraded + shed + rejected + failed
/// still balances exactly.
#[test]
fn flash_crowd_overload_accounts_every_query() {
    let engine: Arc<dyn InferenceEngine> =
        Arc::new(DelayMockEngine::new(8, 3, Duration::from_millis(2)));
    let svc = Service::builder(Arc::new(ApproxIferCode::new(CodeParams::new(4, 1, 0))))
        .engine(engine)
        .batch_deadline(Duration::from_millis(5))
        .max_inflight(1)
        .decode_threads(1)
        .admission(AdmissionConfig {
            queue_depth: 4,
            shed_policy: ShedPolicy::ShedBatch,
            default_priority: Priority::Interactive,
        })
        .spawn()
        .unwrap();
    // ~10ms of gentle base load, then 300-odd arrivals at 50k req/s into a
    // depth-4 queue over a pipeline that serves one 4-group per ~8ms+:
    // overload is certain, not probabilistic.
    let trace =
        LoadTrace::FlashCrowd { base: 400.0, spike: 50_000.0, at_ms: 10.0, spike_ms: 500.0 };
    let report =
        drive(&svc, &trace, 320, 8, 23, 4, "approxifer(K=4,S=1,E=0)", "honest").unwrap();
    assert_eq!(report.submitted, 320, "{}", report.line());
    assert!(report.accounting_balances(), "{}", report.line());
    assert!(
        report.shed + report.rejected > 0,
        "the spike must overflow the depth-4 queue: {}",
        report.line()
    );
    assert!(report.served > 0, "{}", report.line());
    assert_eq!(report.failed, 0, "honest fleet must not fail downstream: {}", report.line());
    // The service metrics agree with the report deltas.
    let m = &svc.metrics;
    assert_eq!(
        m.queries_received.get(),
        m.queries_served.get()
            + m.queries_degraded.get()
            + m.queries_shed.get()
            + m.queries_rejected.get()
            + m.queries_failed.get()
    );
    // The shed/served split shows up on the human report line too.
    let line = m.report();
    assert!(line.contains("admission:"), "{line}");
    svc.shutdown();
}

/// Offered load below capacity with admission on: nothing is shed, and the
/// goodput matches the served count (sanity for the bench's curve math).
#[test]
fn underload_with_admission_serves_everything() {
    let engine: Arc<dyn InferenceEngine> = Arc::new(LinearMockEngine::new(8, 3));
    let svc = Service::builder(Arc::new(ApproxIferCode::new(CodeParams::new(4, 1, 0))))
        .engine(engine)
        .batch_deadline(Duration::from_millis(3))
        .admission(AdmissionConfig::default())
        .spawn()
        .unwrap();
    let trace = LoadTrace::Poisson { rate: 300.0 };
    let report =
        drive(&svc, &trace, 60, 8, 31, 0, "approxifer(K=4,S=1,E=0)", "honest").unwrap();
    assert_eq!(report.served, 60, "{}", report.line());
    assert_eq!(report.shed + report.rejected + report.failed, 0, "{}", report.line());
    assert!(report.p50_ms > 0.0 && report.p50_ms <= report.p999_ms, "{}", report.line());
    assert!(report.goodput_rps > 0.0);
    svc.shutdown();
}
