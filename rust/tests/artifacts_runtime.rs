//! Integration over the real artifacts + PJRT runtime. These tests skip
//! (pass vacuously, with a note) when `make artifacts` has not run, so
//! `cargo test` works on a fresh checkout; CI runs `make test` which
//! builds artifacts first.

use std::sync::Arc;

use approxifer::coding::{ApproxIferCode, CodeParams};
use approxifer::data::{Golden, TestSet};
use approxifer::harness::{approxifer_accuracy, base_accuracy};
use approxifer::runtime::{CompiledEncoder, CompiledModel, Manifest, Runtime};
use approxifer::tensor::Tensor;
use approxifer::workers::{InferenceEngine, PjrtEngine};

fn manifest() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("[skip] artifacts/ missing — run `make artifacts`");
            None
        }
    }
}

#[test]
fn golden_vectors_match_python() {
    let Some(manifest) = manifest() else { return };
    assert!(!manifest.golden.is_empty());
    for entry in &manifest.golden {
        let g = Golden::load(&manifest, entry).unwrap();
        let code = ApproxIferCode::new(CodeParams::new(g.k, g.s, g.e));
        // Encode matrix.
        for (a, b) in code.encode_matrix().iter().zip(g.enc_w.data()) {
            assert!((a - b).abs() <= 1e-5, "{}: {a} vs {b}", entry.tag);
        }
        // Decode of python's coded payloads.
        let d = g.queries.shape()[1];
        let payloads: Vec<&[f32]> =
            g.avail.iter().map(|&i| &g.coded.data()[i * d..(i + 1) * d]).collect();
        let decoded = code.decode(&g.avail, &payloads);
        for j in 0..g.k {
            for t in 0..d {
                let (a, b) = (decoded[j][t], g.decoded.data()[j * d + t]);
                assert!(
                    (a - b).abs() <= 1e-3 * (1.0 + b.abs()),
                    "{}: [{j}][{t}] {a} vs {b}",
                    entry.tag
                );
            }
        }
    }
}

#[test]
fn compiled_model_reproduces_training_accuracy() {
    let Some(manifest) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let entry = manifest.model("resnet18_s", "synmnist", 128).unwrap();
    let model = CompiledModel::load(&rt, &manifest.root, entry).unwrap();
    let engine = PjrtEngine::new(model);
    let ts = TestSet::load(&manifest, "synmnist").unwrap();
    let acc = base_accuracy(&engine, &ts, 256).unwrap();
    // The artifact must carry the trained weights (see aot.py
    // print_large_constants) — accuracy within 5 points of build-time.
    assert!(
        (acc - entry.base_test_acc).abs() < 0.05,
        "artifact acc {acc} vs build-time {}",
        entry.base_test_acc
    );
}

#[test]
fn batch1_and_batch128_artifacts_agree() {
    let Some(manifest) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let ts = TestSet::load(&manifest, "syncifar").unwrap();
    let entry1 = manifest.model("lenet5", "syncifar", 1).unwrap();
    let entry128 = manifest.model("lenet5", "syncifar", 128).unwrap();
    let m1 = CompiledModel::load(&rt, &manifest.root, entry1).unwrap();
    let m128 = CompiledModel::load(&rt, &manifest.root, entry128).unwrap();
    let e1 = PjrtEngine::new(m1);
    let e128 = PjrtEngine::new(m128);
    let flat: Vec<f32> = (0..4).flat_map(|i| ts.image(i).iter().copied()).collect();
    let batched = e128.infer_batch(&flat, 4).unwrap();
    for i in 0..4 {
        let single = e1.infer1(ts.image(i)).unwrap();
        for t in 0..single.len() {
            assert!(
                (single[t] - batched[i * 10 + t]).abs() < 1e-3 * (1.0 + single[t].abs()),
                "sample {i} class {t}: {} vs {}",
                single[t],
                batched[i * 10 + t]
            );
        }
    }
}

#[test]
fn pallas_encoder_artifact_matches_host_encoder() {
    let Some(manifest) = manifest() else { return };
    if manifest.encoders.is_empty() {
        eprintln!("[skip] no encoder artifacts");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let entry = &manifest.encoders[0];
    let enc = CompiledEncoder::load(&rt, &manifest.root, entry).unwrap();
    let code = ApproxIferCode::new(CodeParams::new(entry.k, entry.s, entry.e));
    let d = entry.payload;
    let queries: Vec<Vec<f32>> = (0..entry.k)
        .map(|j| (0..d).map(|t| ((j * 7 + t) as f32 * 0.001).sin()).collect())
        .collect();
    let mut flat = Vec::with_capacity(entry.k * d);
    for q in &queries {
        flat.extend_from_slice(q);
    }
    // PJRT (Pallas kernel) encode.
    let coded_pjrt = enc.encode(&Tensor::from_vec(&[entry.k, d], flat)).unwrap();
    // Host encode through the production flat-buffer path.
    let qrefs: Vec<&[f32]> = queries.iter().map(|q| &q[..]).collect();
    let block = approxifer::coding::GroupBlock::from_rows(&qrefs);
    let mut staged = approxifer::coding::BlockBuf::unpooled(code.params().num_workers(), d);
    code.encode_block(&block, &mut staged);
    let coded_host = staged.freeze();
    assert_eq!(coded_pjrt.shape()[0], coded_host.rows());
    for i in 0..coded_host.rows() {
        for t in 0..d {
            let a = coded_pjrt.data()[i * d + t];
            let b = coded_host.row(i)[t];
            assert!(
                (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                "worker {i} elem {t}: pjrt {a} vs host {b}"
            );
        }
    }
}

#[test]
fn full_coded_accuracy_beats_chance_by_far() {
    let Some(manifest) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let entry = manifest.model("resnet18_s", "synfashion", 128).unwrap();
    let model = CompiledModel::load(&rt, &manifest.root, entry).unwrap();
    let engine = Arc::new(PjrtEngine::new(model));
    let ts = TestSet::load(&manifest, "synfashion").unwrap();
    let r =
        approxifer_accuracy(engine.as_ref(), &ts, CodeParams::new(8, 1, 0), None, 256, 5).unwrap();
    assert!(
        r.accuracy() > 0.5,
        "coded accuracy {} should be far above 10% chance",
        r.accuracy()
    );
}
