//! Integration: the remote worker fleet with *real* worker processes.
//!
//! These tests exec the compiled `approxifer` binary's `worker`
//! subcommand over loopback TCP — the full production topology in
//! miniature: bind the fleet listener, let worker processes join, serve
//! coded groups through the unified `Service`, and then do terrible
//! things to the workers (SIGKILL mid-group, going silent) to prove the
//! coordinator's churn handling: a lost worker's in-flight slots resolve
//! as error replies into the existing collect-quota machinery, so groups
//! complete (or fail fast) but never hang.

use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use approxifer::coding::{ApproxIferCode, CodeParams};
use approxifer::coordinator::Service;
use approxifer::workers::{FleetConfig, RemoteFleet};

/// Kill-on-drop guard so a panicking assertion never leaks worker
/// processes into the test runner.
struct Reap(Vec<Child>);

impl Reap {
    fn push(&mut self, c: Child) {
        self.0.push(c);
    }
}

impl Drop for Reap {
    fn drop(&mut self) {
        for c in &mut self.0 {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// Spawn one `approxifer worker` process against the fleet listener.
fn spawn_worker(addr: &str, slot: usize, engine: &str, extra: &[&str]) -> Child {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_approxifer"));
    cmd.arg("worker")
        .arg("--connect")
        .arg(addr)
        .arg("--slot")
        .arg(slot.to_string())
        .arg("--engine")
        .arg(engine)
        .args(extra)
        .stdout(Stdio::null())
        .stderr(Stdio::null());
    cmd.spawn().expect("spawning worker process")
}

fn poll_until(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + timeout;
    loop {
        if cond() {
            return true;
        }
        if Instant::now() >= deadline {
            return false;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// The acceptance-path test: three real worker processes serve a group,
/// one is SIGKILLed mid-group, the group still completes through the
/// straggler budget, and the replacement process counts as a reconnect.
#[test]
fn killed_worker_mid_group_completes_and_reconnect_counts() {
    // K=2, S=1, E=0: three workers, tolerates one loss per group. The
    // miss threshold is high so the kill is observed as a *leave* (reader
    // EOF), not racily as an eviction.
    let fleet = RemoteFleet::bind(
        &FleetConfig {
            bind: "127.0.0.1:0".into(),
            workers: None,
            spare_slots: 0,
            heartbeat: Duration::from_millis(100),
            miss_threshold: 100,
        },
        3,
    )
    .unwrap();
    let addr = fleet.addr().to_string();
    let handle = fleet.handle();

    let mut kids = Reap(Vec::new());
    for slot in 0..3 {
        // 40ms of synthetic compute per task: wide enough to land the
        // kill while the group is in flight.
        kids.push(spawn_worker(&addr, slot, "mock:8:3:40", &["--heartbeat-ms", "50"]));
    }
    assert!(
        handle.wait_for_workers(3, Duration::from_secs(30)),
        "workers never joined: live={}",
        handle.live_workers()
    );

    let svc = Service::builder(Arc::new(ApproxIferCode::new(CodeParams::new(2, 1, 0))))
        .fleet(Box::new(fleet))
        .flush_after(Duration::from_millis(20))
        .group_timeout(Duration::from_secs(15))
        .spawn()
        .unwrap();
    assert_eq!(svc.metrics.fleet_joins.get(), 3, "pre-attach joins must replay into metrics");

    // Two queries fill one K=2 group, fanned out to all three workers.
    let q: Vec<Vec<f32>> = (0..2)
        .map(|j| (0..8).map(|t| ((j * 8 + t) as f32 * 0.1).sin()).collect())
        .collect();
    let handles: Vec<_> = q.iter().map(|x| svc.submit(x.clone())).collect();

    // SIGKILL worker 2 while its 40ms inference is (very likely) still
    // running. Whatever the interleaving, the group must complete: either
    // the reply beat the kill, or the dead connection resolves the slot
    // as an error reply and the decode proceeds on the K fastest.
    std::thread::sleep(Duration::from_millis(15));
    let mut victim = kids.0.remove(2);
    victim.kill().unwrap();
    victim.wait().unwrap();

    for (j, h) in handles.into_iter().enumerate() {
        let pred = h.wait_timeout(Duration::from_secs(20)).expect("group must complete");
        assert_eq!(pred.len(), 3, "query {j}");
        assert!(pred.iter().all(|v| v.is_finite()), "query {j}: {pred:?}");
    }

    // The kill surfaces as fleet churn once the reader sees the reset.
    assert!(
        poll_until(Duration::from_secs(10), || handle.snapshot().leaves >= 1),
        "no leave recorded after SIGKILL: {:?}",
        handle.snapshot()
    );
    assert!(svc.metrics.fleet_leaves.get() >= 1);

    // A replacement process on the same slot is a *reconnect*.
    kids.push(spawn_worker(&addr, 2, "mock:8:3:40", &["--heartbeat-ms", "50"]));
    assert!(
        poll_until(Duration::from_secs(30), || handle.snapshot().reconnects >= 1),
        "replacement worker never counted as reconnect: {:?}",
        handle.snapshot()
    );
    assert!(handle.wait_for_workers(3, Duration::from_secs(30)));
    assert!(svc.metrics.fleet_reconnects.get() >= 1);

    // The healed fleet serves the next group end to end.
    let handles: Vec<_> = q.iter().map(|x| svc.submit(x.clone())).collect();
    for h in handles {
        let pred = h.wait_timeout(Duration::from_secs(20)).expect("post-heal group");
        assert!(pred.iter().all(|v| v.is_finite()));
    }
    assert!(svc.metrics.fleet_heartbeats.get() > 0, "workers should have heartbeated");

    svc.shutdown();
}

/// A worker that goes silent (open socket, no heartbeats, no replies —
/// a hung process) is evicted after `miss_threshold` silent windows.
#[test]
fn silent_worker_is_evicted_by_heartbeat_misses() {
    let fleet = RemoteFleet::bind(
        &FleetConfig {
            bind: "127.0.0.1:0".into(),
            workers: None,
            spare_slots: 0,
            heartbeat: Duration::from_millis(60),
            miss_threshold: 3,
        },
        1,
    )
    .unwrap();
    let addr = fleet.addr().to_string();
    let handle = fleet.handle();

    let mut kids = Reap(Vec::new());
    kids.push(spawn_worker(
        &addr,
        0,
        "mock:4:2",
        &["--heartbeat-ms", "40", "--mute-after-ms", "150"],
    ));
    assert!(handle.wait_for_workers(1, Duration::from_secs(30)), "worker never joined");
    assert!(
        poll_until(Duration::from_secs(10), || handle.snapshot().heartbeats >= 1),
        "no heartbeat before the mute kicked in: {:?}",
        handle.snapshot()
    );

    // After 150ms the worker mutes; ~3 silent 60ms windows later the
    // monitor must evict the slot.
    assert!(
        poll_until(Duration::from_secs(10), || handle.snapshot().evictions >= 1),
        "silent worker was never evicted: {:?}",
        handle.snapshot()
    );
    assert_eq!(handle.live_workers(), 0, "evicted slot must not count as live");

    // RemoteFleet's Drop closes the listener and joins its threads.
    drop(fleet);
}

/// With no workers joined at all, dispatch resolves every slot as an
/// error reply: submissions fail fast through the quota/redispatch
/// ladder instead of hanging until the group timeout.
#[test]
fn unjoined_fleet_fails_groups_fast_instead_of_hanging() {
    let fleet = RemoteFleet::bind(
        &FleetConfig {
            bind: "127.0.0.1:0".into(),
            workers: None,
            spare_slots: 0,
            heartbeat: Duration::from_millis(200),
            miss_threshold: 100,
        },
        3,
    )
    .unwrap();

    let svc = Service::builder(Arc::new(ApproxIferCode::new(CodeParams::new(2, 1, 0))))
        .fleet(Box::new(fleet))
        .flush_after(Duration::from_millis(10))
        .group_timeout(Duration::from_secs(60))
        .spawn()
        .unwrap();

    let t0 = Instant::now();
    let handles: Vec<_> =
        (0..2).map(|_| svc.submit(vec![0.5f32; 8])).collect();
    for h in handles {
        let res = h.wait_timeout(Duration::from_secs(10));
        let err = res.expect_err("no workers: prediction must fail");
        // The failure must come from the service's fail-fast path, not
        // from our client-side patience bound expiring.
        assert!(
            !err.to_string().contains("timed out"),
            "group hung instead of failing fast: {err}"
        );
    }
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "fail-fast took {:?}",
        t0.elapsed()
    );

    svc.shutdown();
}
