//! Integration: the full coded pipeline (encode → workers → collect →
//! decode) over mock engines, property-tested across (K, S, E) and fault
//! placements. No artifacts required.

use std::sync::Arc;
use std::time::Duration;

use approxifer::coding::CodeParams;
use approxifer::coordinator::{FaultPlan, GroupPipeline};
use approxifer::metrics::ServingMetrics;
use approxifer::testing::forall;
use approxifer::workers::{
    ByzantineMode, InferenceEngine, LinearMockEngine, WorkerPool, WorkerSpec,
};

fn smooth_queries(k: usize, d: usize, phase: f32) -> Vec<Vec<f32>> {
    (0..k)
        .map(|j| {
            (0..d).map(|t| ((j as f32) * 0.21 + (t as f32) * 0.013 + phase).sin()).collect()
        })
        .collect()
}

#[test]
fn straggler_pipeline_property() {
    forall("pipeline-stragglers", 12, |g| {
        let k = g.usize_in(2, 10);
        let s = g.usize_in(1, 3);
        let d = g.usize_in(4, 32);
        let c = g.usize_in(2, 10);
        let params = CodeParams::new(k, s, 0);
        let engine = Arc::new(LinearMockEngine::new(d, c));
        let seed = g.rng().next_u64();
        let specs = vec![WorkerSpec::default(); params.num_workers()];
        let pool = WorkerPool::spawn(engine.clone(), &specs, seed);
        let mut pipe = GroupPipeline::new(params);
        let metrics = ServingMetrics::new();
        let queries = smooth_queries(k, d, g.f64_in(0.0, 3.0) as f32);
        let qrefs: Vec<&[f32]> = queries.iter().map(|q| &q[..]).collect();
        let plan = FaultPlan {
            stragglers: g.subset(params.num_workers(), s),
            straggler_delay: Duration::from_millis(150),
            ..FaultPlan::none()
        };
        let out = pipe.infer_group(&pool, &qrefs, &plan, &metrics).unwrap();
        // Invariant 1: stragglers never in the decode set.
        for w in &plan.stragglers {
            assert!(!out.decode_set.contains(w), "straggler {w} used");
        }
        // Invariant 2: K predictions of C classes each.
        assert_eq!(out.predictions.len(), k);
        for p in &out.predictions {
            assert_eq!(p.len(), c);
            assert!(p.iter().all(|x| x.is_finite()));
        }
        // Invariant 3: decode set size == wait_for (fast path).
        assert_eq!(out.decode_set.len(), params.wait_for());
        pool.shutdown();
    });
}

#[test]
fn byzantine_pipeline_property() {
    forall("pipeline-byzantine", 8, |g| {
        let k = g.usize_in(2, 6);
        let e = g.usize_in(1, 2);
        let d = g.usize_in(4, 16);
        let c = g.usize_in(4, 10);
        let params = CodeParams::new(k, 0, e);
        let engine = Arc::new(LinearMockEngine::new(d, c));
        let pool = WorkerPool::spawn(
            engine.clone(),
            &vec![WorkerSpec::default(); params.num_workers()],
            g.rng().next_u64(),
        );
        let mut pipe = GroupPipeline::new(params);
        let metrics = ServingMetrics::new();
        let queries = smooth_queries(k, d, 0.5);
        let qrefs: Vec<&[f32]> = queries.iter().map(|q| &q[..]).collect();
        let byzantine = g.subset(params.num_workers(), e);
        let plan = FaultPlan {
            byzantine: byzantine.clone(),
            byz_mode: Some(ByzantineMode::GaussianNoise { sigma: 25.0 }),
            ..FaultPlan::none()
        };
        let out = pipe.infer_group(&pool, &qrefs, &plan, &metrics).unwrap();
        // With strong noise on smooth linear predictions the vote locator
        // must find the corrupted workers.
        assert_eq!(out.flagged, byzantine, "locator missed the adversaries");
        // Decoded predictions stay close to the honest reference.
        for (j, q) in queries.iter().enumerate() {
            let want = engine.infer1(q).unwrap();
            for t in 0..c {
                let err = (out.predictions[j][t] - want[t]).abs();
                assert!(err < 1.0, "q{j} c{t}: {} vs {}", out.predictions[j][t], want[t]);
            }
        }
        pool.shutdown();
    });
}

#[test]
fn zero_and_signflip_adversaries_also_located() {
    for mode in [ByzantineMode::SignFlip, ByzantineMode::RandomLogits { scale: 20.0 }] {
        let params = CodeParams::new(4, 0, 1);
        // Payload scaled up so sign-flip is a large perturbation.
        let engine = Arc::new(LinearMockEngine::new(8, 6));
        let pool = WorkerPool::spawn(
            engine.clone(),
            &vec![WorkerSpec::default(); params.num_workers()],
            77,
        );
        let mut pipe = GroupPipeline::new(params);
        let metrics = ServingMetrics::new();
        let queries: Vec<Vec<f32>> = (0..4)
            .map(|j| (0..8).map(|t| 10.0 * ((j * 3 + t) as f32 * 0.2).sin()).collect())
            .collect();
        let qrefs: Vec<&[f32]> = queries.iter().map(|q| &q[..]).collect();
        let plan = FaultPlan {
            byzantine: vec![5],
            byz_mode: Some(mode),
            ..FaultPlan::none()
        };
        let out = pipe.infer_group(&pool, &qrefs, &plan, &metrics).unwrap();
        assert_eq!(out.flagged, vec![5], "mode {mode:?} not located");
        pool.shutdown();
    }
}

#[test]
fn encode_drop_corrupt_decode_roundtrip_property() {
    // The full codec contract across random (K, S, E) up to K = 8, f = id:
    // encode a smooth query family, drop exactly S random workers
    // (stragglers), corrupt exactly E random survivors, then
    // locate + decode. The locator must pinpoint the corruptions and the
    // relative decode error must stay within a conservative Berrut
    // approximation bound (Berrut's interpolant converges O(h) on smooth
    // functions; two interpolation passes over ≥ 2K+E nodes of a gentle
    // sine keep the error well under 0.35 of the unit amplitude).
    use approxifer::coding::{ApproxIferCode, BlockPool, RowView};
    use approxifer::coordinator::locate_and_decode;
    use approxifer::tensor::Tensor;

    forall("scheme-roundtrip-kse", 20, |g| {
        let k = g.usize_in(2, 8);
        let s = g.usize_in(0, 2);
        let e = g.usize_in(1, 2);
        let params = CodeParams::new(k, s, e);
        let code = ApproxIferCode::new(params);
        let nw = params.num_workers();
        let d = 6usize;
        // Smooth per-coordinate curves sampled at the query nodes α_j
        // (gentle frequencies: at K=2 the decoder interpolates from just
        // two α nodes, so the payload must be resolvable at that density).
        let freq: Vec<f64> = (0..d).map(|_| g.f64_in(0.3, 1.2)).collect();
        let phase: Vec<f64> = (0..d).map(|_| g.f64_in(0.0, 3.0)).collect();
        let sample = |a: f64| -> Vec<f32> {
            (0..d).map(|t| (freq[t] * a + phase[t]).sin() as f32).collect()
        };
        let queries: Vec<Tensor> =
            code.alpha().iter().map(|&a| Tensor::from_vec(&[d], sample(a))).collect();
        let coded = code.encode(&queries);
        // Drop exactly S workers; corrupt exactly E of the survivors.
        let dropped = g.subset(nw, s);
        let alive: Vec<usize> = (0..nw).filter(|i| !dropped.contains(i)).collect();
        let byz: Vec<usize> =
            g.subset(alive.len(), e).into_iter().map(|p| alive[p]).collect();
        let mut replies: Vec<Option<RowView>> = vec![None; nw];
        for &i in &alive {
            replies[i] = Some(RowView::from_vec(coded[i].data().to_vec()));
        }
        for &b in &byz {
            let mut reply = replies[b].as_deref().unwrap().to_vec();
            for v in reply.iter_mut() {
                let delta = 5.0 + g.rng().normal(0.0, 15.0).abs();
                *v += if g.bool() { delta as f32 } else { -delta as f32 };
            }
            replies[b] = Some(RowView::from_vec(reply));
        }
        let metrics = ServingMetrics::new();
        let blocks = BlockPool::new();
        let (decoded, decode_set, flagged) = locate_and_decode(
            &code,
            approxifer::coding::LocatorMethod::Pinned,
            &replies,
            &metrics,
            &blocks,
        )
        .unwrap();
        assert_eq!(flagged, byz, "K={k} S={s} E={e}: locator missed the corruptions");
        for &b in &byz {
            assert!(!decode_set.contains(&b));
        }
        // The error bound: Berrut's O(h) ≈ O(1/K) approximation error,
        // amplified by the decode subset's conditioning — dropping or
        // excluding nodes breaks the alternating-sign cancellation, and the
        // surviving subset's Lebesgue mass Σ|ℓ̂| scales the error
        // accordingly (the same scaling scheme.rs uses for its exactness
        // tests). Empirically the worst case sits at ~0.7·(1.5/K)·Λ over
        // thousands of sampled configurations, so this asserts with ~1.4×
        // margin while staying sharp for well-conditioned subsets.
        let w = code.decode_matrix(&decode_set);
        let f = decode_set.len();
        let mut leb = 1.0f64;
        for j in 0..k {
            let mass: f64 = w[j * f..(j + 1) * f].iter().map(|&x| (x as f64).abs()).sum();
            leb = leb.max(mass);
        }
        let tol = ((1.5 / k as f64) * leb) as f32;
        for (j, &a) in code.alpha().iter().enumerate() {
            let want = sample(a);
            for t in 0..d {
                let err = (decoded[j][t] - want[t]).abs();
                assert!(
                    err < tol,
                    "K={k} S={s} E={e} j={j} t={t}: {} vs {} (err {err}, leb {leb:.1})",
                    decoded[j][t],
                    want[t]
                );
            }
        }
    });
}

#[test]
fn repeated_groups_are_deterministic_in_math() {
    // Two pipelines over the same queries and fault plans decode to the
    // same predictions (thread scheduling must not leak into results).
    let params = CodeParams::new(6, 1, 0);
    let engine = Arc::new(LinearMockEngine::new(12, 5));
    let queries = smooth_queries(6, 12, 1.0);
    let qrefs: Vec<&[f32]> = queries.iter().map(|q| &q[..]).collect();
    let plan = FaultPlan {
        stragglers: vec![2],
        straggler_delay: Duration::from_millis(120),
        ..FaultPlan::none()
    };
    let run = || {
        let pool = WorkerPool::spawn(
            engine.clone(),
            &vec![WorkerSpec::default(); params.num_workers()],
            1,
        );
        let mut pipe = GroupPipeline::new(params);
        let metrics = ServingMetrics::new();
        let out = pipe.infer_group(&pool, &qrefs, &plan, &metrics).unwrap();
        pool.shutdown();
        out
    };
    let a = run();
    let b = run();
    assert_eq!(a.decode_set, b.decode_set);
    assert_eq!(a.predictions, b.predictions);
}
