//! Adaptive control-plane integration tests: a deterministic drift
//! scenario (honest fleet → Byzantine burst → recovery) asserting the
//! controller raises `E` within one window and sheds it after the burst,
//! plus bit-identical replay with the control plane disabled and the SLO
//! hedge riding alongside adaptation.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use approxifer::coding::{ApproxIferCode, CodeParams, RowView};
use approxifer::coordinator::{AdaptiveConfig, FaultPlan, Service, VerifyPolicy};
use approxifer::sim::faults::FaultProfile;
use approxifer::workers::{ByzantineMode, InferenceEngine, LinearMockEngine};

const K: usize = 4;
const D: usize = 8;

fn group_queries(group: usize) -> Vec<Vec<f32>> {
    (0..K)
        .map(|j| {
            let i = (group * K + j) as f32;
            (0..D).map(|t| (i * 0.19 + (t as f32) * 0.023).sin()).collect()
        })
        .collect()
}

/// Serve `n` closed-loop groups; returns the last group's predictions.
fn run_groups(svc: &Service, start: usize, n: usize) -> Vec<RowView> {
    let mut last = Vec::new();
    for g in start..start + n {
        let queries = group_queries(g);
        let handles: Vec<_> = queries.iter().map(|q| svc.submit(q.clone())).collect();
        last = handles
            .into_iter()
            .map(|h| h.wait_timeout(Duration::from_secs(20)).expect("group served"))
            .collect();
    }
    last
}

/// The controller's decision and the batcher's application of it are
/// asynchronous to the served groups: poll briefly before asserting. The
/// observations that *drive* the decision are all in by the time this is
/// called — only the epoch hand-off is in flight.
fn await_current_e(svc: &Service, want: u64) {
    for _ in 0..400 {
        if svc.metrics.current_e.get() == want {
            return;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(svc.metrics.current_e.get(), want, "controller never settled");
}

#[test]
fn controller_raises_e_in_one_window_and_sheds_it_after_the_burst() {
    let engine = Arc::new(LinearMockEngine::new(D, 3));
    // Provisioned (S=1, E=1): an 11-worker fleet the controller tunes
    // within. The fault plan is swapped between phases through the hook;
    // the closed loop guarantees no group straddles a phase.
    let plan: Arc<Mutex<FaultPlan>> = Arc::new(Mutex::new(FaultPlan::none()));
    let hook = {
        let plan = plan.clone();
        Arc::new(move |_g: u64| plan.lock().unwrap().clone())
    };
    let svc = Service::builder(Arc::new(ApproxIferCode::new(CodeParams::new(K, 1, 1))))
        .engine(engine.clone())
        .flush_after(Duration::from_millis(1))
        .max_inflight(1)
        .decode_threads(1)
        .verify(VerifyPolicy::on(0.4))
        .adaptive(AdaptiveConfig { window: 4, cooldown: 1, ..AdaptiveConfig::default() })
        .fault_hook(hook.clone())
        .spawn()
        .unwrap();
    assert_eq!(svc.metrics.current_e.get(), 1, "starts at the provisioned point");

    // Phase A — honest drift-down: one calm window (cooldown 1) sheds the
    // unused Byzantine budget. S holds: without an SLO the straggler loop
    // is inert.
    run_groups(&svc, 0, 5);
    await_current_e(&svc, 0);
    assert_eq!(svc.metrics.current_s.get(), 1, "no SLO: S must hold");

    // Phase B — Byzantine burst: worker 0 corrupts every reply; worker 4
    // (the straggler spare) is delayed so the fastest-4-of-5 collection is
    // deterministic and always contains the corruption. At E=0 the decode
    // cannot locate it: verification fails, the redispatch rung fails
    // again, and the evidence raises E within one window (two groups —
    // each failed group contributes the redispatch and the degraded-serve
    // observation).
    *plan.lock().unwrap() = FaultPlan {
        byzantine: vec![0],
        byz_mode: Some(ByzantineMode::GaussianNoise { sigma: 10.0 }),
        stragglers: vec![4],
        straggler_delay: Duration::from_millis(80),
        ..FaultPlan::none()
    };
    let last = run_groups(&svc, 5, 8);
    await_current_e(&svc, 1); // raised within one window of the burst
    assert!(svc.metrics.verify_failures.get() >= 1);
    assert!(svc.metrics.redispatches.get() >= 1);
    // With E restored the adversary is located and excluded: the last
    // burst group decodes accurately again.
    let queries = group_queries(5 + 8 - 1);
    for (q, p) in queries.iter().zip(&last) {
        let want = engine.infer1(q).unwrap();
        for (a, b) in want.iter().zip(p.iter()) {
            assert!((a - b).abs() < 0.3, "post-raise decode inaccurate: {a} vs {b}");
        }
    }

    // Phase C — recovery: calm windows shed the budget again.
    *plan.lock().unwrap() = FaultPlan::none();
    run_groups(&svc, 13, 10);
    await_current_e(&svc, 0); // recovery sheds E again
    assert!(svc.metrics.reconfigure_epochs.get() >= 3, "down, up, down again");
    assert_eq!(svc.metrics.adaptive_alerts.get(), 0);
    svc.shutdown();
}

#[test]
fn replay_is_bit_identical_with_adaptive_disabled() {
    // (K=4, S=0, E=1) waits for every reply, so the decode set is not a
    // race; with adaptive.enabled=false the serving path must replay a
    // seeded Byzantine profile bit-identically.
    let run = || {
        let engine = Arc::new(LinearMockEngine::new(D, 3));
        let params = CodeParams::new(K, 0, 1);
        let profile =
            FaultProfile::parse("byz-random:1:10", params.num_workers(), 42).unwrap();
        let svc = Service::builder(Arc::new(ApproxIferCode::new(params)))
            .engine(engine)
            .flush_after(Duration::from_millis(1))
            .max_inflight(1)
            .decode_threads(1)
            .verify(VerifyPolicy::on(0.4))
            .seed(42)
            .fault_profile(profile)
            .spawn()
            .unwrap();
        let mut all = Vec::new();
        for g in 0..6 {
            all.extend(run_groups(&svc, g, 1));
        }
        let epochs = svc.metrics.reconfigure_epochs.get();
        svc.shutdown();
        (all, epochs)
    };
    let (a, ea) = run();
    let (b, eb) = run();
    assert_eq!(ea, 0, "no control plane, no epochs");
    assert_eq!(eb, 0);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x, y, "replay must be bit-identical");
    }
}

#[test]
fn slo_hedge_rides_alongside_the_control_plane() {
    // Two 60ms stragglers stall the full 10-of-11 quota at (S=1, E=1);
    // the 20ms SLO hedges the group through with the 9 fast replies
    // (2(K+E)-1, the locator's rank floor). The
    // controller sees the misses but S is already at the provisioned
    // ceiling, so the service keeps hedging instead of thrashing.
    let engine = Arc::new(LinearMockEngine::new(D, 3));
    let svc = Service::builder(Arc::new(ApproxIferCode::new(CodeParams::new(K, 1, 1))))
        .engine(engine)
        .flush_after(Duration::from_millis(1))
        .max_inflight(1)
        .decode_threads(1)
        .verify(VerifyPolicy::on(0.4))
        .slo(Duration::from_millis(20))
        .group_timeout(Duration::from_secs(5))
        .adaptive(AdaptiveConfig { window: 2, cooldown: 10, ..AdaptiveConfig::default() })
        .fault_hook(Arc::new(|_g| FaultPlan {
            stragglers: vec![0, 1],
            straggler_delay: Duration::from_millis(60),
            ..FaultPlan::none()
        }))
        .spawn()
        .unwrap();
    run_groups(&svc, 0, 4);
    assert!(svc.metrics.hedge_attempts.get() >= 1, "hedge must fire");
    assert!(svc.metrics.slo_misses.get() >= 1);
    assert_eq!(svc.metrics.groups_failed.get(), 0, "hedged groups must not also time out");
    assert_eq!(
        svc.metrics.current_s.get(),
        1,
        "S is clamped at the provisioned ceiling, no thrash"
    );
    svc.shutdown();
}
