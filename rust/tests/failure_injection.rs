//! Failure-injection integration tests: worker errors, timeouts, late
//! replies, partial groups — the unhappy paths of the coordinator.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use approxifer::coding::CodeParams;
use approxifer::coordinator::{FaultPlan, GroupPipeline};
use approxifer::metrics::ServingMetrics;
use approxifer::workers::{InferenceEngine, LinearMockEngine, WorkerPool, WorkerSpec};

/// Engine that fails on every `fail_every`-th call.
struct FlakyEngine {
    inner: LinearMockEngine,
    calls: AtomicUsize,
    fail_every: usize,
}

impl FlakyEngine {
    fn new(payload: usize, classes: usize, fail_every: usize) -> FlakyEngine {
        FlakyEngine {
            inner: LinearMockEngine::new(payload, classes),
            calls: AtomicUsize::new(0),
            fail_every,
        }
    }
}

impl InferenceEngine for FlakyEngine {
    fn payload(&self) -> usize {
        self.inner.payload()
    }

    fn classes(&self) -> usize {
        self.inner.classes()
    }

    fn infer1(&self, payload: &[f32]) -> Result<Vec<f32>> {
        let n = self.calls.fetch_add(1, Ordering::SeqCst);
        if self.fail_every > 0 && n % self.fail_every == self.fail_every - 1 {
            anyhow::bail!("injected engine failure (call {n})");
        }
        self.inner.infer1(payload)
    }
}

fn smooth_queries(k: usize, d: usize) -> Vec<Vec<f32>> {
    (0..k)
        .map(|j| (0..d).map(|t| ((j as f32) * 0.23 + (t as f32) * 0.017).sin()).collect())
        .collect()
}

#[test]
fn engine_failures_are_tolerated_like_stragglers() {
    // 1 failure per 10 calls; S=2 spare capacity absorbs occasional losses.
    let params = CodeParams::new(4, 2, 0);
    let engine = Arc::new(FlakyEngine::new(8, 3, 10));
    let pool = WorkerPool::spawn(engine, &vec![WorkerSpec::default(); params.num_workers()], 1);
    let mut pipe = GroupPipeline::new(params);
    pipe.timeout = Duration::from_secs(5);
    let metrics = ServingMetrics::new();
    let queries = smooth_queries(4, 8);
    let qrefs: Vec<&[f32]> = queries.iter().map(|q| &q[..]).collect();
    let mut ok = 0;
    for _ in 0..20 {
        // A group can still fail if > S workers error in the same group —
        // with fail_every=10 and 6 workers that's rare; count successes.
        if pipe.infer_group(&pool, &qrefs, &FaultPlan::none(), &metrics).is_ok() {
            ok += 1;
        }
    }
    assert!(ok >= 15, "only {ok}/20 groups succeeded");
    assert!(metrics.errors.get() > 0, "injection never fired");
    pool.shutdown();
}

#[test]
fn timeout_on_too_many_stragglers_is_clean_error() {
    // Straggle MORE workers than S tolerates: the group must time out with
    // a descriptive error, not hang or panic.
    let params = CodeParams::new(3, 1, 0);
    let engine = Arc::new(LinearMockEngine::new(6, 2));
    let pool = WorkerPool::spawn(engine, &vec![WorkerSpec::default(); params.num_workers()], 2);
    let mut pipe = GroupPipeline::new(params);
    pipe.timeout = Duration::from_millis(100);
    let metrics = ServingMetrics::new();
    let queries = smooth_queries(3, 6);
    let qrefs: Vec<&[f32]> = queries.iter().map(|q| &q[..]).collect();
    let plan = FaultPlan {
        stragglers: vec![0, 1], // S+1 stragglers: only 2 fast replies < K=3
        straggler_delay: Duration::from_secs(10),
        ..FaultPlan::none()
    };
    let err = match pipe.infer_group(&pool, &qrefs, &plan, &metrics) {
        Err(e) => e,
        Ok(_) => panic!("group should have timed out"),
    };
    assert!(format!("{err:#}").contains("timed out"), "{err:#}");
    pool.shutdown();
}

#[test]
fn late_replies_from_timed_out_group_are_discarded() {
    let params = CodeParams::new(3, 1, 0);
    let engine = Arc::new(LinearMockEngine::new(6, 2));
    let pool = WorkerPool::spawn(engine, &vec![WorkerSpec::default(); params.num_workers()], 3);
    let mut pipe = GroupPipeline::new(params);
    pipe.timeout = Duration::from_millis(80);
    let metrics = ServingMetrics::new();
    let queries = smooth_queries(3, 6);
    let qrefs: Vec<&[f32]> = queries.iter().map(|q| &q[..]).collect();
    // Group 1 times out (2 workers straggle for 300ms).
    let plan = FaultPlan {
        stragglers: vec![0, 1],
        straggler_delay: Duration::from_millis(300),
        ..FaultPlan::none()
    };
    assert!(pipe.infer_group(&pool, &qrefs, &plan, &metrics).is_err());
    // Group 2 runs clean while group 1's late replies drain in.
    std::thread::sleep(Duration::from_millis(350));
    let out = pipe.infer_group(&pool, &qrefs, &FaultPlan::none(), &metrics).unwrap();
    assert_eq!(out.predictions.len(), 3);
    assert!(
        metrics.stragglers_cancelled.get() > 0,
        "late replies should have been counted as cancelled"
    );
    pool.shutdown();
}

#[test]
fn pool_shutdown_mid_group_does_not_hang() {
    let params = CodeParams::new(3, 1, 0);
    let engine = Arc::new(LinearMockEngine::new(6, 2));
    let pool = WorkerPool::spawn(engine, &vec![WorkerSpec::default(); params.num_workers()], 4);
    // Send tasks then immediately shut down.
    for w in 0..params.num_workers() {
        pool.send(
            w,
            approxifer::workers::WorkerTask {
                group: 1,
                payload: vec![0.0; 6],
                extra_delay: Duration::from_millis(50),
                corrupt: None,
            },
        )
        .unwrap();
    }
    pool.shutdown(); // must join, not deadlock
}
