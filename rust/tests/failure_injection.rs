//! Failure-injection integration tests: worker errors, timeouts, late
//! replies, partial groups, and the named fault-profile matrix (crash /
//! slow-tail / flaky / random-Byzantine / colluding-Byzantine) with
//! verified decode — the unhappy paths of the coordinator. Every profile
//! scenario is deterministic under its fixed seed.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use approxifer::coding::{ApproxIferCode, CodeParams};
use approxifer::coordinator::{FaultPlan, GroupPipeline, Service, VerifyPolicy};
use approxifer::metrics::ServingMetrics;
use approxifer::sim::faults::FaultProfile;
use approxifer::workers::{
    ByzantineMode, InferenceEngine, LinearMockEngine, WorkerPool, WorkerSpec,
};

/// Engine that fails on every `fail_every`-th call.
struct FlakyEngine {
    inner: LinearMockEngine,
    calls: AtomicUsize,
    fail_every: usize,
}

impl FlakyEngine {
    fn new(payload: usize, classes: usize, fail_every: usize) -> FlakyEngine {
        FlakyEngine {
            inner: LinearMockEngine::new(payload, classes),
            calls: AtomicUsize::new(0),
            fail_every,
        }
    }
}

impl InferenceEngine for FlakyEngine {
    fn payload(&self) -> usize {
        self.inner.payload()
    }

    fn classes(&self) -> usize {
        self.inner.classes()
    }

    fn infer1(&self, payload: &[f32]) -> Result<Vec<f32>> {
        let n = self.calls.fetch_add(1, Ordering::SeqCst);
        if self.fail_every > 0 && n % self.fail_every == self.fail_every - 1 {
            anyhow::bail!("injected engine failure (call {n})");
        }
        self.inner.infer1(payload)
    }
}

fn smooth_queries(k: usize, d: usize) -> Vec<Vec<f32>> {
    (0..k)
        .map(|j| (0..d).map(|t| ((j as f32) * 0.23 + (t as f32) * 0.017).sin()).collect())
        .collect()
}

#[test]
fn engine_failures_are_tolerated_like_stragglers() {
    // 1 failure per 10 calls; S=2 spare capacity absorbs occasional losses.
    let params = CodeParams::new(4, 2, 0);
    let engine = Arc::new(FlakyEngine::new(8, 3, 10));
    let pool = WorkerPool::spawn(engine, &vec![WorkerSpec::default(); params.num_workers()], 1);
    let mut pipe = GroupPipeline::new(params);
    pipe.timeout = Duration::from_secs(5);
    let metrics = ServingMetrics::new();
    let queries = smooth_queries(4, 8);
    let qrefs: Vec<&[f32]> = queries.iter().map(|q| &q[..]).collect();
    let mut ok = 0;
    for _ in 0..20 {
        // A group can still fail if > S workers error in the same group —
        // with fail_every=10 and 6 workers that's rare; count successes.
        if pipe.infer_group(&pool, &qrefs, &FaultPlan::none(), &metrics).is_ok() {
            ok += 1;
        }
    }
    assert!(ok >= 15, "only {ok}/20 groups succeeded");
    assert!(metrics.errors.get() > 0, "injection never fired");
    pool.shutdown();
}

#[test]
fn timeout_on_too_many_stragglers_is_clean_error() {
    // Straggle MORE workers than S tolerates: the group must time out with
    // a descriptive error, not hang or panic.
    let params = CodeParams::new(3, 1, 0);
    let engine = Arc::new(LinearMockEngine::new(6, 2));
    let pool = WorkerPool::spawn(engine, &vec![WorkerSpec::default(); params.num_workers()], 2);
    let mut pipe = GroupPipeline::new(params);
    pipe.timeout = Duration::from_millis(100);
    let metrics = ServingMetrics::new();
    let queries = smooth_queries(3, 6);
    let qrefs: Vec<&[f32]> = queries.iter().map(|q| &q[..]).collect();
    let plan = FaultPlan {
        stragglers: vec![0, 1], // S+1 stragglers: only 2 fast replies < K=3
        straggler_delay: Duration::from_secs(10),
        ..FaultPlan::none()
    };
    let err = match pipe.infer_group(&pool, &qrefs, &plan, &metrics) {
        Err(e) => e,
        Ok(_) => panic!("group should have timed out"),
    };
    assert!(format!("{err:#}").contains("timed out"), "{err:#}");
    pool.shutdown();
}

#[test]
fn late_replies_from_timed_out_group_are_discarded() {
    let params = CodeParams::new(3, 1, 0);
    let engine = Arc::new(LinearMockEngine::new(6, 2));
    let pool = WorkerPool::spawn(engine, &vec![WorkerSpec::default(); params.num_workers()], 3);
    let mut pipe = GroupPipeline::new(params);
    pipe.timeout = Duration::from_millis(80);
    let metrics = ServingMetrics::new();
    let queries = smooth_queries(3, 6);
    let qrefs: Vec<&[f32]> = queries.iter().map(|q| &q[..]).collect();
    // Group 1 times out (2 workers straggle for 300ms).
    let plan = FaultPlan {
        stragglers: vec![0, 1],
        straggler_delay: Duration::from_millis(300),
        ..FaultPlan::none()
    };
    assert!(pipe.infer_group(&pool, &qrefs, &plan, &metrics).is_err());
    // Group 2 runs clean while group 1's late replies drain in.
    std::thread::sleep(Duration::from_millis(350));
    let out = pipe.infer_group(&pool, &qrefs, &FaultPlan::none(), &metrics).unwrap();
    assert_eq!(out.predictions.len(), 3);
    assert!(
        metrics.stragglers_cancelled.get() > 0,
        "late replies should have been counted as cancelled"
    );
    pool.shutdown();
}

// ---- the named fault-profile matrix ------------------------------------

/// Pool whose worker behaviors come from a named profile.
fn profiled_pool(
    params: CodeParams,
    spec: &str,
    seed: u64,
    payload: usize,
    classes: usize,
) -> (WorkerPool, FaultProfile, Arc<LinearMockEngine>) {
    let profile = FaultProfile::parse(spec, params.num_workers(), seed).unwrap();
    let engine = Arc::new(LinearMockEngine::new(payload, classes));
    let specs: Vec<WorkerSpec> = profile
        .behaviors
        .iter()
        .map(|&b| WorkerSpec::default().with_behavior(b))
        .collect();
    let pool = WorkerPool::spawn(engine.clone(), &specs, seed);
    (pool, profile, engine)
}

#[test]
fn named_profiles_replay_bit_identically() {
    // The acceptance contract: every named profile expands to the same
    // fleet assignment under a fixed seed.
    for spec in
        ["crash:2@4", "slow:2:1:40:0.5", "flaky:2:0.3", "byz-random:2:10", "byz-collude:2:15"]
    {
        let a = FaultProfile::parse(spec, 10, 0xFEED).unwrap();
        let b = FaultProfile::parse(spec, 10, 0xFEED).unwrap();
        assert_eq!(a, b, "profile '{spec}' must be deterministic");
        assert_eq!(a.faulty().len(), 2, "profile '{spec}'");
    }
}

#[test]
fn crash_profile_is_tolerated_within_slack() {
    // K=3, S=2: N = K+S-1 = 4 → five workers, decoder waits for the
    // fastest 3. Two workers crash at their 2nd request — the first two
    // groups see the full fleet, later groups run on the 3 survivors,
    // which covers wait_for exactly (zero remaining slack: any further
    // fault in this test would time groups out).
    let params = CodeParams::new(3, 2, 0);
    let (pool, profile, _engine) = profiled_pool(params, "crash:2@2", 11, 8, 4);
    let crashed = profile.faulty();
    assert_eq!(crashed.len(), 2);
    let mut pipe = GroupPipeline::new(params);
    pipe.timeout = Duration::from_secs(5);
    let metrics = ServingMetrics::new();
    let queries = smooth_queries(3, 8);
    let qrefs: Vec<&[f32]> = queries.iter().map(|q| &q[..]).collect();
    for g in 0..6 {
        let out = pipe
            .infer_group(&pool, &qrefs, &FaultPlan::none(), &metrics)
            .unwrap_or_else(|e| panic!("group {g} failed: {e:#}"));
        if g >= 2 {
            for w in &crashed {
                assert!(!out.decode_set.contains(w), "group {g} used crashed worker {w}");
            }
        }
    }
    pool.shutdown();
}

#[test]
fn slow_tail_profile_is_ridden_out() {
    // One worker delays every reply by a constant 60ms (base, no tail).
    // With S=1 the decoder's fastest-subset collection must never include
    // it: the code absorbs the straggler with zero added latency.
    let params = CodeParams::new(4, 1, 0);
    let (pool, profile, _engine) = profiled_pool(params, "slow:1:60:0:1", 12, 8, 4);
    let slow = profile.faulty();
    assert_eq!(slow.len(), 1);
    let mut pipe = GroupPipeline::new(params);
    pipe.timeout = Duration::from_secs(5);
    let metrics = ServingMetrics::new();
    let queries = smooth_queries(4, 8);
    let qrefs: Vec<&[f32]> = queries.iter().map(|q| &q[..]).collect();
    for _ in 0..3 {
        let out = pipe.infer_group(&pool, &qrefs, &FaultPlan::none(), &metrics).unwrap();
        assert!(!out.decode_set.contains(&slow[0]), "slow worker in decode set");
    }
    pool.shutdown();
}

#[test]
fn flaky_profile_errors_are_absorbed() {
    // One worker errors on every request (p_fail = 1); with S=1 slack the
    // remaining workers still reach the wait count and every group decodes.
    let params = CodeParams::new(3, 1, 0);
    let (pool, profile, _engine) = profiled_pool(params, "flaky:1:1.0", 13, 8, 4);
    assert_eq!(profile.faulty().len(), 1);
    let mut pipe = GroupPipeline::new(params);
    pipe.timeout = Duration::from_secs(5);
    let metrics = ServingMetrics::new();
    let queries = smooth_queries(3, 8);
    let qrefs: Vec<&[f32]> = queries.iter().map(|q| &q[..]).collect();
    for _ in 0..5 {
        pipe.infer_group(&pool, &qrefs, &FaultPlan::none(), &metrics).unwrap();
    }
    // The error reply races the honest replies against wait_for, so not
    // every one is observed before collection stops — but across 5 groups
    // at least one must be.
    assert!(metrics.errors.get() >= 1, "flaky worker never errored");
    pool.shutdown();
}

#[test]
fn random_byzantine_profile_is_located_and_verified() {
    // One Gaussian-noise adversary within the E=1 budget: located,
    // excluded, and the decode passes re-encode verification.
    let params = CodeParams::new(3, 0, 1);
    let (pool, profile, engine) = profiled_pool(params, "byz-random:1:20", 14, 8, 6);
    let byz = profile.faulty();
    assert_eq!(byz.len(), 1);
    let mut pipe =
        GroupPipeline::new(params).with_verification(VerifyPolicy::on(0.4));
    pipe.timeout = Duration::from_secs(5);
    let metrics = ServingMetrics::new();
    let queries = smooth_queries(3, 8);
    let qrefs: Vec<&[f32]> = queries.iter().map(|q| &q[..]).collect();
    let out = pipe.infer_group(&pool, &qrefs, &FaultPlan::none(), &metrics).unwrap();
    assert_eq!(out.flagged, byz, "locator missed the noisy adversary");
    let report = out.verify.expect("verification ran");
    assert!(report.passed, "residual {} failed verification", report.residual);
    for (j, q) in queries.iter().enumerate() {
        let want = engine.infer1(q).unwrap();
        for t in 0..6 {
            assert!((out.predictions[j][t] - want[t]).abs() < 0.6, "q{j} c{t}");
        }
    }
    pool.shutdown();
}

#[test]
fn colluding_byzantine_detected_and_verified_at_e2() {
    // The acceptance scenario: E = 2 colluding adversaries injecting
    // *identical* per-group corruption — the attack that defeats
    // majority/comparison defenses. The rational locator must still flag
    // both, the decode must pass verification, and the whole scenario must
    // replay bit-identically under its fixed seed.
    let params = CodeParams::new(3, 0, 2);
    let seed = 0xC0FFEE;
    let run = || {
        let (pool, profile, engine) = profiled_pool(params, "byz-collude:2:15", seed, 8, 6);
        let mut pipe =
            GroupPipeline::new(params).with_verification(VerifyPolicy::on(0.4));
        pipe.timeout = Duration::from_secs(5);
        let metrics = ServingMetrics::new();
        let queries = smooth_queries(3, 8);
        let qrefs: Vec<&[f32]> = queries.iter().map(|q| &q[..]).collect();
        let out = pipe.infer_group(&pool, &qrefs, &FaultPlan::none(), &metrics).unwrap();
        pool.shutdown();
        (out, profile.faulty(), engine, queries)
    };
    let (out, colluders, engine, queries) = run();
    assert_eq!(colluders.len(), 2);
    assert_eq!(out.flagged, colluders, "locator must flag both colluders");
    for w in &colluders {
        assert!(!out.decode_set.contains(w));
    }
    let report = out.verify.expect("verification ran");
    assert!(report.passed, "residual {} failed verification", report.residual);
    assert!(!report.escalated, "pinned locate should hold on the first rung");
    for (j, q) in queries.iter().enumerate() {
        let want = engine.infer1(q).unwrap();
        for t in 0..6 {
            assert!(
                (out.predictions[j][t] - want[t]).abs() < 0.6,
                "q{j} c{t}: {} vs {}",
                out.predictions[j][t],
                want[t]
            );
        }
    }
    // Bit-identical replay: S = 0 means the decode set is scheduling-free
    // and the colluders' corruption is keyed to (pact, group).
    let (out2, colluders2, _engine, _queries) = run();
    assert_eq!(colluders2, colluders);
    assert_eq!(out2.flagged, out.flagged);
    assert_eq!(out2.predictions, out.predictions, "replay must be bit-identical");
}

#[test]
fn verification_failure_redispatches_and_recovers() {
    // Rung 3 of the escalation ladder, end to end: group 1 is corrupted
    // *beyond* the E = 1 budget (two colluding workers), so both locate
    // rungs produce inconsistent decodes and the coordinator redispatches.
    // The redispatched group (id 2) is clean, verifies, and the clients
    // get accurate answers — transparently.
    let engine = Arc::new(LinearMockEngine::new(8, 6));
    let svc = Service::builder(Arc::new(ApproxIferCode::new(CodeParams::new(2, 0, 1))))
        .engine(engine.clone())
        .flush_after(Duration::from_millis(5))
        .verify(VerifyPolicy::on(0.4))
        .fault_hook(Arc::new(|group| {
            if group == 1 {
                FaultPlan {
                    byzantine: vec![0, 1],
                    byz_mode: Some(ByzantineMode::Colluding { pact: 777, scale: 25.0 }),
                    ..FaultPlan::none()
                }
            } else {
                FaultPlan::none()
            }
        }))
        .spawn()
        .unwrap();
    let queries = smooth_queries(2, 8);
    let handles: Vec<_> = queries.iter().map(|q| svc.submit(q.clone())).collect();
    for (j, h) in handles.into_iter().enumerate() {
        let pred = h.wait_timeout(Duration::from_secs(10)).unwrap();
        let want = engine.infer1(&queries[j]).unwrap();
        for t in 0..6 {
            assert!(
                (pred[t] - want[t]).abs() < 0.6,
                "q{j} c{t}: {} vs {} (redispatch must recover accuracy)",
                pred[t],
                want[t]
            );
        }
    }
    assert_eq!(svc.metrics.redispatches.get(), 1, "exactly one redispatch");
    assert!(svc.metrics.verify_failures.get() >= 1);
    assert!(svc.metrics.verify_escalations.get() >= 1);
    assert_eq!(svc.metrics.groups_decoded.get(), 1);
    svc.shutdown();
}

#[test]
fn persistent_overbudget_corruption_serves_degraded_not_hung() {
    // If every dispatch (including the redispatch) is corrupted beyond
    // budget, the service must still answer — degraded, observable in the
    // metrics — rather than hang or error the group.
    let engine = Arc::new(LinearMockEngine::new(8, 6));
    let svc = Service::builder(Arc::new(ApproxIferCode::new(CodeParams::new(2, 0, 1))))
        .engine(engine)
        .flush_after(Duration::from_millis(5))
        .verify(VerifyPolicy::on(0.4))
        .fault_hook(Arc::new(|_group| FaultPlan {
            byzantine: vec![0, 1],
            byz_mode: Some(ByzantineMode::Colluding { pact: 4242, scale: 25.0 }),
            ..FaultPlan::none()
        }))
        .spawn()
        .unwrap();
    let queries = smooth_queries(2, 8);
    let handles: Vec<_> = queries.iter().map(|q| svc.submit(q.clone())).collect();
    for h in handles {
        assert!(h.wait_timeout(Duration::from_secs(10)).is_ok(), "degraded group must answer");
    }
    assert_eq!(svc.metrics.redispatches.get(), 1, "redispatch budget is one");
    assert!(svc.metrics.verify_failures.get() >= 2, "both dispatches must fail verification");
    svc.shutdown();
}

#[test]
fn pool_shutdown_mid_group_does_not_hang() {
    let params = CodeParams::new(3, 1, 0);
    let engine = Arc::new(LinearMockEngine::new(6, 2));
    let pool = WorkerPool::spawn(engine, &vec![WorkerSpec::default(); params.num_workers()], 4);
    // Send tasks then immediately shut down.
    for w in 0..params.num_workers() {
        pool.send(
            w,
            approxifer::workers::WorkerTask {
                group: 1,
                payload: approxifer::coding::RowView::from_vec(vec![0.0; 6]),
                extra_delay: Duration::from_millis(50),
                corrupt: None,
            },
        )
        .unwrap();
    }
    pool.shutdown(); // must join, not deadlock
}
