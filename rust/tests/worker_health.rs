//! Worker health plane integration tests: a persistent Byzantine slot is
//! convicted, quarantined, and replaced by a spare (bit-identically under
//! a fixed seed); the collect-quota clamp keeps a spare-less fleet
//! serving; and a transiently-faulty slot earns its way back through
//! probation. Everything runs through the real service stack — batcher,
//! dispatcher, health gate, decode verification — not plane unit calls.

use std::sync::Arc;
use std::time::Duration;

use approxifer::coding::{ApproxIferCode, CodeParams};
use approxifer::coordinator::{FaultPlan, Service, VerifyPolicy};
use approxifer::sim::faults::Behavior;
use approxifer::workers::{
    ByzantineMode, HealthConfig, HealthGate, HealthPlane, InferenceEngine, LinearMockEngine,
    SlotState, WorkerPool, WorkerSpec,
};

fn smooth_queries(k: usize, d: usize) -> Vec<Vec<f32>> {
    (0..k)
        .map(|j| (0..d).map(|t| ((j as f32) * 0.23 + (t as f32) * 0.017).sin()).collect())
        .collect()
}

/// Two convicted groups cross the threshold: 2.0, then 2.0·0.5 + 2.0 = 3.0.
fn quick_cfg() -> HealthConfig {
    HealthConfig {
        quarantine_threshold: 2.5,
        decay: 0.5,
        conviction_weight: 2.0,
        error_weight: 1.0,
        straggle_weight: 0.0, // keep scheduling jitter out of the score
        heartbeat_weight: 2.5,
        probation_ms: 600_000, // scenarios lower this when probation is the point
        probation_passes: 2,
        emergency_verify_failures: 3,
    }
}

fn assert_bits_eq(a: &[Vec<Vec<f32>>], b: &[Vec<Vec<f32>>], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: round counts differ");
    for (r, (ra, rb)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(ra.len(), rb.len(), "{what}: round {r} query counts differ");
        for (q, (pa, pb)) in ra.iter().zip(rb.iter()).enumerate() {
            assert_eq!(pa.len(), pb.len(), "{what}: round {r} q{q} widths differ");
            for (t, (x, y)) in pa.iter().zip(pb.iter()).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{what}: round {r} q{q} c{t}: {x} vs {y} (must be bit-identical)"
                );
            }
        }
    }
}

fn assert_accurate(pred: &[f32], want: &[f32], ctx: &str) {
    for (t, (p, w)) in pred.iter().zip(want.iter()).enumerate() {
        assert!((p - w).abs() < 0.6, "{ctx} c{t}: {p} vs {w}");
    }
}

#[test]
fn byzantine_slot_is_quarantined_and_spare_backfills_bit_identically() {
    // K=2, S=0, E=1 → 6 logical positions, quota = all 6 replies (the S=0
    // decode set is scheduling-free, which is what makes the replay
    // bit-identical). The pool carries a 7th honest worker as the spare.
    let params = CodeParams::new(2, 0, 1);
    let nw = params.num_workers();
    assert_eq!(nw, 6);
    let rounds = 6;
    let queries = smooth_queries(2, 8);

    let run = || {
        let engine = Arc::new(LinearMockEngine::new(8, 6));
        let mut specs = vec![WorkerSpec::default(); nw + 1];
        specs[2] = WorkerSpec::default().with_behavior(Behavior::Byzantine(
            ByzantineMode::Colluding { pact: 99, scale: 20.0 },
        ));
        let pool = WorkerPool::spawn(engine.clone(), &specs, 0xA11CE);
        let plane = Arc::new(HealthPlane::new(quick_cfg(), 0xA11CE));
        let gate = HealthGate::attach(Box::new(pool), nw, plane.clone());
        let svc = Service::builder(Arc::new(ApproxIferCode::new(params)))
            .fleet(Box::new(gate))
            .health_plane(plane.clone(), 0)
            .verify(VerifyPolicy::on(0.4))
            .flush_after(Duration::from_millis(50))
            .seed(7)
            .spawn()
            .unwrap();
        let mut preds: Vec<Vec<Vec<f32>>> = Vec::new();
        for r in 0..rounds {
            let handles: Vec<_> = queries.iter().map(|q| svc.submit(q.clone())).collect();
            let round: Vec<Vec<f32>> = handles
                .into_iter()
                .map(|h| h.wait_timeout(Duration::from_secs(10)).unwrap().to_vec())
                .collect();
            for (j, p) in round.iter().enumerate() {
                let want = engine.infer1(&queries[j]).unwrap();
                assert_accurate(p, &want, &format!("round {r} q{j}"));
            }
            preds.push(round);
        }
        let stats = plane.stats();
        let snap = plane.snapshot();
        let metric = svc.metrics.worker_quarantines.get();
        svc.shutdown();
        (preds, stats, snap, metric)
    };

    let (preds, stats, snap, metric) = run();
    assert_eq!(stats.quarantines, 1, "exactly one quarantine: {stats:?}");
    assert_eq!(metric, 1, "worker_quarantines metric");
    assert_eq!(stats.suppressed, 0, "the spare backfilled; nothing was suppressed");
    assert_eq!(snap[2].state, SlotState::Quarantined);
    assert!(snap[2].convictions >= 2, "snapshot: {:?}", snap[2]);
    assert_eq!(snap[2].logical, None, "quarantined physical must be unmapped");
    assert_eq!(snap[6].logical, Some(2), "spare must take over logical position 2");

    // Replay: the whole scenario — including the quarantine round — is
    // bit-identical under the fixed seeds.
    let (preds2, stats2, _snap2, _metric2) = run();
    assert_eq!(stats2.quarantines, 1);
    assert_bits_eq(&preds, &preds2, "replay");

    // Honest baseline: once the spare holds slot 2 the fleet is all-honest,
    // so post-quarantine rounds must match an untouched service bit for
    // bit — quarantine heals the fleet completely, not approximately.
    let engine = Arc::new(LinearMockEngine::new(8, 6));
    let base = Service::builder(Arc::new(ApproxIferCode::new(params)))
        .engine(engine)
        .verify(VerifyPolicy::on(0.4))
        .flush_after(Duration::from_millis(50))
        .seed(7)
        .spawn()
        .unwrap();
    let handles: Vec<_> = queries.iter().map(|q| base.submit(q.clone())).collect();
    let base_round: Vec<Vec<f32>> = handles
        .into_iter()
        .map(|h| h.wait_timeout(Duration::from_secs(10)).unwrap().to_vec())
        .collect();
    base.shutdown();
    // Quarantine lands while observing round 1 (scores 2.0 → 3.0 > 2.5);
    // the backfill is enacted at round 2's dispatch.
    for r in 2..rounds {
        assert_bits_eq(
            &[preds[r].clone()],
            &[base_round.clone()],
            &format!("post-quarantine round {r} vs honest baseline"),
        );
    }
}

#[test]
fn quarantine_never_drops_live_slots_below_the_collect_quota() {
    // Same adversary, but the pool is exactly as wide as the scheme: no
    // spare, and the S=0 quota needs every position. The clamp must keep
    // the quarantined slot serving (marked, not suppressed) — degraded,
    // never deadlocked.
    let params = CodeParams::new(2, 0, 1);
    let nw = params.num_workers();
    let engine = Arc::new(LinearMockEngine::new(8, 6));
    let mut specs = vec![WorkerSpec::default(); nw];
    specs[2] = WorkerSpec::default().with_behavior(Behavior::Byzantine(
        ByzantineMode::Colluding { pact: 55, scale: 20.0 },
    ));
    let pool = WorkerPool::spawn(engine.clone(), &specs, 0xC1A);
    let plane = Arc::new(HealthPlane::new(quick_cfg(), 0xC1A));
    let gate = HealthGate::attach(Box::new(pool), nw, plane.clone());
    let svc = Service::builder(Arc::new(ApproxIferCode::new(params)))
        .fleet(Box::new(gate))
        .health_plane(plane.clone(), 0)
        .verify(VerifyPolicy::on(0.4))
        .flush_after(Duration::from_millis(50))
        .spawn()
        .unwrap();
    let queries = smooth_queries(2, 8);
    for r in 0..5 {
        let handles: Vec<_> = queries.iter().map(|q| svc.submit(q.clone())).collect();
        for (j, h) in handles.into_iter().enumerate() {
            let pred = h.wait_timeout(Duration::from_secs(10)).unwrap();
            let want = engine.infer1(&queries[j]).unwrap();
            assert_accurate(&pred, &want, &format!("round {r} q{j}"));
        }
    }
    let stats = plane.stats();
    let snap = plane.snapshot();
    svc.shutdown();
    assert_eq!(stats.quarantines, 1, "{stats:?}");
    assert_eq!(stats.suppressed, 0, "quota leaves no room to suppress: {stats:?}");
    assert_eq!(stats.probations, 0, "a clamped slot must not be probed: {stats:?}");
    assert!(snap[2].clamped, "slot 2 must be clamped in place: {:?}", snap[2]);
    assert_eq!(snap[2].state, SlotState::Quarantined);
    assert_eq!(snap[2].logical, Some(2), "clamped slot keeps its position");
}

#[test]
fn transient_fault_is_probationed_and_reinstated() {
    // The fault lives in the *task stream* (per-group fault hook), not the
    // worker: groups 1–2 corrupt logical position 2, later groups are
    // clean. The plane quarantines physical 2, the spare takes the
    // position, and shadow probes — cross-checked bitwise against verified
    // decodes — reinstate physical 2 into the spare pool.
    let params = CodeParams::new(2, 0, 1);
    let nw = params.num_workers();
    let engine = Arc::new(LinearMockEngine::new(8, 6));
    let pool =
        WorkerPool::spawn(engine.clone(), &vec![WorkerSpec::default(); nw + 1], 0xBEE);
    let mut cfg = quick_cfg();
    cfg.probation_ms = 0; // probe at the first post-quarantine dispatch
    let plane = Arc::new(HealthPlane::new(cfg, 0xBEE));
    let gate = HealthGate::attach(Box::new(pool), nw, plane.clone());
    let svc = Service::builder(Arc::new(ApproxIferCode::new(params)))
        .fleet(Box::new(gate))
        .health_plane(plane.clone(), 0)
        .verify(VerifyPolicy::on(0.4))
        .flush_after(Duration::from_millis(20))
        .fault_hook(Arc::new(|group| {
            if group <= 2 {
                FaultPlan {
                    byzantine: vec![2],
                    byz_mode: Some(ByzantineMode::Colluding { pact: 41, scale: 20.0 }),
                    ..FaultPlan::none()
                }
            } else {
                FaultPlan::none()
            }
        }))
        .spawn()
        .unwrap();
    let queries = smooth_queries(2, 8);
    // A probe only counts when its reply lands before the group decodes,
    // so drive rounds until two land (bounded — inconclusive probes re-arm).
    let mut reinstated = false;
    for r in 0..30 {
        let handles: Vec<_> = queries.iter().map(|q| svc.submit(q.clone())).collect();
        for (j, h) in handles.into_iter().enumerate() {
            let pred = h.wait_timeout(Duration::from_secs(10)).unwrap();
            let want = engine.infer1(&queries[j]).unwrap();
            assert_accurate(&pred, &want, &format!("round {r} q{j}"));
        }
        if plane.stats().reinstated == 1 {
            reinstated = true;
            break;
        }
    }
    let stats = plane.stats();
    assert!(reinstated, "slot 2 was never reinstated: {stats:?}");
    assert_eq!(stats.quarantines, 1, "{stats:?}");
    assert!(stats.probations >= 1, "{stats:?}");
    assert_eq!(svc.metrics.worker_reinstated.get(), 1);
    assert!(svc.metrics.worker_probations.get() >= 1);
    let snap = plane.snapshot();
    assert_eq!(snap[2].state, SlotState::Active, "{:?}", snap[2]);
    assert_eq!(snap[2].score, 0.0, "reinstatement resets the score");
    assert_eq!(snap[2].logical, None, "reinstated physical rejoins the spare pool");
    assert_eq!(snap[6].logical, Some(2), "the backfill spare keeps the position");
    // The healed fleet keeps serving.
    let handles: Vec<_> = queries.iter().map(|q| svc.submit(q.clone())).collect();
    for (j, h) in handles.into_iter().enumerate() {
        let pred = h.wait_timeout(Duration::from_secs(10)).unwrap();
        let want = engine.infer1(&queries[j]).unwrap();
        assert_accurate(&pred, &want, &format!("post-reinstatement q{j}"));
    }
    svc.shutdown();
}
