//! Multi-tenant serving integration: two schemes sharing one worker
//! fleet, Byzantine-neighbor isolation (the headline property of the
//! fairness scheduler's in-flight budgets), and the per-tenant + global
//! accounting invariant under admission-gate shedding.

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use approxifer::coding::CodeParams;
use approxifer::coordinator::{
    AdaptiveConfig, FaultPlan, Strategy, TenantRegistry, TenantSpec, VerifyPolicy,
};
use approxifer::workers::{
    ByzantineMode, DelayMockEngine, InferenceEngine, LinearMockEngine, WorkerPool, WorkerSpec,
};

const D: usize = 6;

fn query(i: usize) -> Vec<f32> {
    (0..D).map(|t| ((i as f32) * 0.19 + (t as f32) * 0.023).sin()).collect()
}

/// A shared pool hosting both tenants' models: slot 0 = alpha's engine,
/// slot 1 = beta's, selected per task by the tenant tag in the group id.
fn shared_pool(
    engines: Vec<Arc<dyn InferenceEngine>>,
    workers: usize,
    seed: u64,
) -> WorkerPool {
    WorkerPool::spawn_multi(engines, &vec![WorkerSpec::default(); workers], seed, None)
}

fn spec(name: &str, params: CodeParams) -> TenantSpec {
    TenantSpec {
        name: name.into(),
        strategy: Strategy::ApproxIfer,
        params,
        batch_deadline: Duration::from_millis(2),
        ..TenantSpec::default()
    }
}

#[test]
fn two_schemes_serve_concurrently_and_accurately_over_one_fleet() {
    let alpha_engine = Arc::new(LinearMockEngine::new(D, 3));
    let beta_engine = Arc::new(LinearMockEngine::new(D, 5));
    // alpha (2,1,0) needs 3 workers, beta (4,1,0) needs 5: the fleet is
    // sized for the largest tenant and shared by both.
    let pool = shared_pool(vec![alpha_engine.clone(), beta_engine.clone()], 5, 17);
    let registry = TenantRegistry::spawn(
        Box::new(pool),
        vec![spec("alpha", CodeParams::new(2, 1, 0)), spec("beta", CodeParams::new(4, 1, 0))],
        4,
    )
    .unwrap();

    let alpha = registry.tenants()[0].service.clone();
    let beta = registry.tenants()[1].service.clone();
    let alpha_thread = std::thread::spawn(move || {
        let handles: Vec<_> = (0..20).map(|i| alpha.submit(query(i))).collect();
        handles
            .into_iter()
            .map(|h| h.wait_timeout(Duration::from_secs(20)).expect("alpha served").to_vec())
            .collect::<Vec<_>>()
    });
    let beta_preds: Vec<Vec<f32>> = {
        let handles: Vec<_> = (0..20).map(|i| beta.submit(query(i))).collect();
        handles
            .into_iter()
            .map(|h| h.wait_timeout(Duration::from_secs(20)).expect("beta served").to_vec())
            .collect()
    };
    let alpha_preds = alpha_thread.join().unwrap();

    // Each tenant's answers come from *its* model — right width, right
    // values (Berrut decode is approximate, hence the tolerance).
    for (i, p) in alpha_preds.iter().enumerate() {
        let want = alpha_engine.infer1(&query(i)).unwrap();
        assert_eq!(p.len(), 3, "alpha prediction width");
        for (a, b) in want.iter().zip(p) {
            assert!((a - b).abs() < 0.3, "alpha query {i}: {a} vs {b}");
        }
    }
    for (i, p) in beta_preds.iter().enumerate() {
        let want = beta_engine.infer1(&query(i)).unwrap();
        assert_eq!(p.len(), 5, "beta prediction width");
        for (a, b) in want.iter().zip(p) {
            assert!((a - b).abs() < 0.3, "beta query {i}: {a} vs {b}");
        }
    }
    let grants = registry.scheduler().grants();
    assert!(grants[0] > 0 && grants[1] > 0, "both tenants dispatched: {grants:?}");
    registry.assert_balanced().unwrap();
    drop(beta);
    registry.shutdown();
}

// ---------------------------------------------------------------------------
// Byzantine-neighbor isolation
// ---------------------------------------------------------------------------

/// Everything observable about tenant B after a run: its predictions, its
/// accounting counters and its adaptive `(S, E)` operating point.
struct BRun {
    preds: Vec<Vec<f32>>,
    accounting: approxifer::coordinator::Accounting,
    s: u64,
    e: u64,
    max_latency: Duration,
}

/// Serve tenant B's fixed closed-loop workload over the shared fleet,
/// with or without a Byzantine neighbor (tenant A under a byz-random
/// fault hook) hammering the same workers concurrently.
///
/// Determinism notes, because the comparison below is `==` on floats:
/// * B's code points have `S = 0`, so every group's collection quota is
///   the *full* dispatch set — the decode always sees the same worker
///   subset, not a timing-dependent "fastest" one.
/// * B's groups are phase-gated on the adaptive gauge: 4 groups fill the
///   observation window at E=1, then the run waits for the controller's
///   shed-to-0 epoch to land before serving the last 2 — so each group's
///   epoch (and hence its decode geometry) is pinned, not racing the
///   asynchronous reconfigure hand-off.
fn run_b(with_byz_neighbor: bool) -> BRun {
    let engines: Vec<Arc<dyn InferenceEngine>> =
        vec![Arc::new(LinearMockEngine::new(D, 3)), Arc::new(LinearMockEngine::new(D, 5))];
    // A (2,1,1) needs 7 workers; B (4,0,1) needs 10. B runs adaptive with
    // verification so its (S, E) gauges are live state, not constants.
    let pool = shared_pool(engines, 10, 42);
    let mut spec_a = spec("alpha", CodeParams::new(2, 1, 1));
    spec_a.verify = VerifyPolicy::on(0.4);
    let mut spec_b = spec("beta", CodeParams::new(4, 0, 1));
    spec_b.verify = VerifyPolicy::on(0.4);
    spec_b.adaptive = Some(AdaptiveConfig { window: 4, cooldown: 1, ..Default::default() });
    let registry = TenantRegistry::spawn_with(
        Box::new(pool),
        vec![spec_a, spec_b],
        8,
        |i, b| {
            if i == 0 {
                // Tenant A's dispatches corrupt worker 0 every group. The
                // hook is per-service: only A's groups carry the fault.
                b.fault_hook(Arc::new(|_g| FaultPlan {
                    byzantine: vec![0],
                    byz_mode: Some(ByzantineMode::GaussianNoise { sigma: 10.0 }),
                    ..FaultPlan::none()
                }))
            } else {
                b
            }
        },
    )
    .unwrap();

    let a_thread = with_byz_neighbor.then(|| {
        let svc = registry.tenants()[0].service.clone();
        std::thread::spawn(move || {
            let handles: Vec<_> = (0..12).map(|i| svc.submit(query(100 + i))).collect();
            for h in handles {
                // A's answers may be degraded under its own corruption;
                // they must still all resolve.
                let _ = h.wait_timeout(Duration::from_secs(30)).expect("alpha resolved");
            }
        })
    });

    let svc_b = registry.tenants()[1].service.clone();
    let mut preds = Vec::new();
    let mut max_latency = Duration::ZERO;
    let mut serve_groups = |range: std::ops::Range<usize>| {
        for g in range {
            let t0 = Instant::now();
            let handles: Vec<_> = (0..4).map(|j| svc_b.submit(query(g * 4 + j))).collect();
            for h in handles {
                preds
                    .push(h.wait_timeout(Duration::from_secs(30)).expect("beta served").to_vec());
            }
            max_latency = max_latency.max(t0.elapsed());
        }
    };
    // Phase 1: one full observation window at the provisioned E=1.
    serve_groups(0..4);
    // B is honest, so one calm window (cooldown 1) sheds the unused
    // Byzantine budget; wait out the asynchronous epoch hand-off so
    // phase 2 runs entirely at E=0.
    for _ in 0..400 {
        if svc_b.metrics.current_e.get() == 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(svc_b.metrics.current_e.get(), 0, "B's controller never shed E");
    // Phase 2: the post-shed epoch.
    serve_groups(4..6);
    if let Some(t) = a_thread {
        t.join().unwrap();
    }
    registry.assert_balanced().unwrap();
    let out = BRun {
        preds,
        accounting: registry.accounting(1),
        s: svc_b.metrics.current_s.get(),
        e: svc_b.metrics.current_e.get(),
        max_latency,
    };
    drop(svc_b);
    registry.shutdown();
    out
}

#[test]
fn byzantine_neighbor_leaves_an_honest_tenant_bit_identical() {
    let alone = run_b(false);
    let shared = run_b(true);

    // The isolation contract: everything deterministic about B — its
    // decoded predictions, its query accounting and its adaptive (S, E)
    // operating point — is bit-identical whether or not a Byzantine
    // neighbor shares the fleet. Wall-clock latency is the one axis that
    // cannot be bit-identical (B shares physical workers with A), so the
    // tail is bounded loosely instead: the fairness budget keeps B's
    // groups flowing, it does not freeze the clock.
    assert_eq!(alone.preds.len(), shared.preds.len());
    for (i, (a, b)) in alone.preds.iter().zip(&shared.preds).enumerate() {
        assert_eq!(a, b, "B's prediction {i} changed under a Byzantine neighbor");
    }
    assert_eq!(alone.accounting, shared.accounting, "B's accounting changed");
    assert_eq!((alone.s, alone.e), (shared.s, shared.e), "B's (S, E) changed");
    assert_eq!(shared.accounting.received, 24);
    assert_eq!(shared.accounting.served, 24, "honest B must serve everything");
    assert!(
        shared.max_latency < Duration::from_secs(10),
        "B's worst group took {:?} next to a Byzantine neighbor",
        shared.max_latency
    );
}

// ---------------------------------------------------------------------------
// Accounting under shed + fairness under flood
// ---------------------------------------------------------------------------

#[test]
fn accounting_balances_per_tenant_and_globally_under_shedding() {
    let engines: Vec<Arc<dyn InferenceEngine>> =
        vec![Arc::new(LinearMockEngine::new(D, 3)), Arc::new(LinearMockEngine::new(D, 5))];
    let pool = shared_pool(engines, 5, 23);
    let mut spec_a = spec("alpha", CodeParams::new(2, 1, 0));
    // A tiny admission queue: an open-loop flood must overflow it, and
    // every overflow victim still lands in exactly one terminal class.
    spec_a.queue_depth = Some(4);
    let spec_b = spec("beta", CodeParams::new(4, 1, 0));
    let registry =
        TenantRegistry::spawn(Box::new(pool), vec![spec_a, spec_b], 4).unwrap();

    let (tx, rx) = channel();
    let alpha = &registry.tenants()[0].service;
    for i in 0..200u64 {
        alpha.submit_tagged(i, query(i as usize), tx.clone());
    }
    drop(tx);
    let mut answered = 0;
    while rx.recv().is_ok() {
        answered += 1;
    }
    assert_eq!(answered, 200, "every open-loop submission resolves exactly once");

    let beta = &registry.tenants()[1].service;
    let handles: Vec<_> = (0..8).map(|i| beta.submit(query(i))).collect();
    for h in handles {
        h.wait_timeout(Duration::from_secs(20)).expect("beta served");
    }

    let a = registry.accounting(0);
    assert_eq!(a.received, 200);
    assert!(a.rejected > 0 || a.shed > 0, "the flood must overflow queue_depth=4: {a:?}");
    assert!(a.balanced(), "{a:?}");
    let g = registry.global_accounting();
    assert_eq!(g.received, 208);
    registry.assert_balanced().unwrap();
    registry.shutdown();
}

#[test]
fn a_flooding_tenant_cannot_starve_its_neighbor() {
    // Alpha's model is slow (2ms/task) and alpha floods open-loop with 8×
    // beta's weight; the shared capacity (3) is below the summed budgets,
    // so every dispatch is contended. Beta's closed-loop groups must still
    // flow: the in-flight budget caps alpha at 2 slots, leaving one for
    // beta whenever it asks.
    let engines: Vec<Arc<dyn InferenceEngine>> = vec![
        Arc::new(DelayMockEngine::new(D, 3, Duration::from_millis(2))),
        Arc::new(LinearMockEngine::new(D, 5)),
    ];
    let pool = shared_pool(engines, 5, 31);
    let mut spec_a = spec("alpha", CodeParams::new(2, 1, 0));
    spec_a.weight = 8;
    spec_a.budget = 2;
    let mut spec_b = spec("beta", CodeParams::new(4, 1, 0));
    spec_b.weight = 1;
    spec_b.budget = 2;
    let registry =
        TenantRegistry::spawn(Box::new(pool), vec![spec_a, spec_b], 3).unwrap();

    let alpha = registry.tenants()[0].service.clone();
    let flood = std::thread::spawn(move || {
        let (tx, rx) = channel();
        for i in 0..300u64 {
            alpha.submit_tagged(i, query(i as usize), tx.clone());
        }
        drop(tx);
        while rx.recv().is_ok() {}
    });

    let beta = &registry.tenants()[1].service;
    let t0 = Instant::now();
    for g in 0..10 {
        let handles: Vec<_> = (0..4).map(|j| beta.submit(query(g * 4 + j))).collect();
        for h in handles {
            h.wait_timeout(Duration::from_secs(20))
                .expect("beta starved behind the flooding tenant");
        }
    }
    let beta_wall = t0.elapsed();
    flood.join().unwrap();
    assert!(
        beta_wall < Duration::from_secs(15),
        "beta's 10 groups took {beta_wall:?} behind the flood"
    );
    let grants = registry.scheduler().grants();
    assert!(grants[1] >= 10, "beta got {} grants", grants[1]);
    registry.assert_balanced().unwrap();
    registry.shutdown();
}
