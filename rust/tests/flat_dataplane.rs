//! Flat-buffer data-plane conformance: the blocked-GEMM codec must be
//! **bit-identical** to the retained naive reference across the whole
//! (K, S, E) × payload-size space (including payloads not divisible by the
//! GEMM tile and subset decodes), and the buffer pool's recycled blocks
//! must be fully overwritten by every producer (no stale floats leaking
//! between groups).

use std::sync::Arc;

use approxifer::coding::linalg::GEMM_BLOCK;
use approxifer::coding::{
    ApproxIferCode, BlockBuf, BlockPool, CodeParams, GroupBlock, ParmProxy, Replication,
    RowView, ServingScheme, Uncoded, VerifyPolicy,
};
use approxifer::metrics::ServingMetrics;
use approxifer::testing::forall;

/// Payload lengths that straddle the kernel tile: 1, tiny, odd primes, the
/// tile edge ±1, and a multi-tile ragged size.
const PAYLOAD_SIZES: [usize; 8] =
    [1, 3, 17, 100, GEMM_BLOCK - 1, GEMM_BLOCK, GEMM_BLOCK + 13, 2 * GEMM_BLOCK + 101];

fn random_queries(g: &mut approxifer::testing::Gen, k: usize, d: usize) -> Vec<Vec<f32>> {
    (0..k)
        .map(|_| (0..d).map(|_| (g.f64_in(-3.0, 3.0)) as f32).collect())
        .collect()
}

#[test]
fn encode_gemm_is_bit_identical_to_reference_forall_kse_and_ragged_d() {
    forall("flat-encode-conformance", 40, |g| {
        let k = g.usize_in(1, 25);
        // Guard degeneracy: E = 0 needs N = K+S-1 >= 1.
        let s = g.usize_in(if k == 1 { 1 } else { 0 }, 3);
        let e = g.usize_in(0, 3);
        let d = PAYLOAD_SIZES[g.usize_in(0, PAYLOAD_SIZES.len() - 1)];
        let code = ApproxIferCode::new(CodeParams::new(k, s, e));
        let nw = code.params().num_workers();
        let queries = random_queries(g, k, d);
        let qrefs: Vec<&[f32]> = queries.iter().map(|q| &q[..]).collect();
        let block = GroupBlock::from_rows(&qrefs);
        let mut fast = BlockBuf::unpooled(nw, d);
        let mut slow = BlockBuf::unpooled(nw, d);
        code.encode_block(&block, &mut fast);
        code.encode_reference(&block, &mut slow);
        for (i, (a, b)) in fast.as_slice().iter().zip(slow.as_slice()).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "K={k} S={s} E={e} d={d} elem {i}: blocked {a} vs naive {b}"
            );
        }
    });
}

#[test]
fn subset_decode_gemm_is_bit_identical_to_reference() {
    forall("flat-decode-conformance", 40, |g| {
        let k = g.usize_in(1, 12);
        let s = g.usize_in(1, 3);
        let e = g.usize_in(0, 2);
        let code = ApproxIferCode::new(CodeParams::new(k, s, e));
        let nw = code.params().num_workers();
        let d = PAYLOAD_SIZES[g.usize_in(0, PAYLOAD_SIZES.len() - 1)];
        // Random availability subset of random size — ragged decode shapes
        // included, not just the canonical decode_set_size().
        let m = g.usize_in(1, nw);
        let avail = g.subset(nw, m);
        let payloads_owned = random_queries(g, m, d);
        let payloads: Vec<&[f32]> = payloads_owned.iter().map(|p| &p[..]).collect();
        let pool = BlockPool::new();
        let fast = code.decode_block(&avail, &payloads, &pool);
        let slow = code.decode_reference(&avail, &payloads);
        assert_eq!(fast.rows(), k);
        for j in 0..k {
            for (t, (a, b)) in fast.row(j).iter().zip(&slow[j]).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "K={k} S={s} E={e} d={d} |F|={m} row {j} elem {t}: {a} vs {b}"
                );
            }
        }
        // The allocating convenience path rides the same kernel.
        let mid = code.decode(&avail, &payloads);
        for j in 0..k {
            assert_eq!(&mid[j][..], fast.row(j));
        }
    });
}

#[test]
fn recycled_blocks_are_fully_overwritten_by_every_scheme_encoder() {
    // Poison a pooled buffer with NaN, recycle it, and encode through each
    // scheme: the output must carry no NaN (every element written) and be
    // bitwise equal to the same encode into fresh memory — recycled blocks
    // can never leak a previous group's floats.
    let k = 4;
    let d = GEMM_BLOCK + 7; // ragged: the tile tail must be overwritten too
    let queries: Vec<Vec<f32>> =
        (0..k).map(|j| (0..d).map(|t| ((j * 31 + t) as f32 * 0.01).sin()).collect()).collect();
    let qrefs: Vec<&[f32]> = queries.iter().map(|q| &q[..]).collect();
    let block = GroupBlock::from_rows(&qrefs);
    let schemes: Vec<Arc<dyn ServingScheme>> = vec![
        Arc::new(ApproxIferCode::new(CodeParams::new(k, 1, 1))),
        Arc::new(Replication::new(k, 1, 1)),
        Arc::new(ParmProxy::new(k)),
        Arc::new(Uncoded::new(k)),
    ];
    for scheme in schemes {
        let nw = scheme.num_workers();
        let pool = BlockPool::new();
        // Poison, then retire the buffer to the free list.
        {
            let mut poisoned = pool.take(nw, d);
            poisoned.as_mut_slice().fill(f32::NAN);
            drop(poisoned);
        }
        assert_eq!(pool.free_buffers(), 1);
        let mut recycled = pool.take(nw, d);
        assert_eq!(pool.reused(), 1, "{}: take must reuse the poisoned buffer", scheme.name());
        assert!(
            recycled.as_slice().iter().all(|v| v.is_nan()),
            "{}: pool.take must NOT zero (the overwrite contract is the producer's)",
            scheme.name()
        );
        scheme.encode_into(&block, &mut recycled);
        let mut fresh = BlockBuf::unpooled(nw, d);
        scheme.encode_into(&block, &mut fresh);
        for (i, (a, b)) in recycled.as_slice().iter().zip(fresh.as_slice()).enumerate() {
            assert!(!a.is_nan(), "{}: stale NaN survived at {i}", scheme.name());
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{}: recycled encode differs from fresh at {i}",
                scheme.name()
            );
        }
    }
}

#[test]
fn recycled_decode_output_blocks_are_fully_overwritten() {
    let code = ApproxIferCode::new(CodeParams::new(3, 1, 0));
    let d = 37;
    let queries: Vec<Vec<f32>> =
        (0..3).map(|j| (0..d).map(|t| ((j * 7 + t) as f32 * 0.05).sin()).collect()).collect();
    let qrefs: Vec<&[f32]> = queries.iter().map(|q| &q[..]).collect();
    let block = GroupBlock::from_rows(&qrefs);
    let mut staged = BlockBuf::unpooled(code.params().num_workers(), d);
    code.encode_block(&block, &mut staged);
    let coded = staged.freeze();
    let avail: Vec<usize> = (0..3).collect();
    let payloads: Vec<&[f32]> = avail.iter().map(|&i| coded.row(i)).collect();
    let pool = BlockPool::new();
    {
        let mut poisoned = pool.take(3, d);
        poisoned.as_mut_slice().fill(f32::NAN);
        drop(poisoned);
    }
    let out = code.decode_block(&avail, &payloads, &pool);
    assert_eq!(pool.reused(), 1, "decode must have taken the poisoned buffer");
    assert!(
        out.data().iter().all(|v| !v.is_nan()),
        "stale NaN leaked through a recycled decode block"
    );
    let reference = code.decode_reference(&avail, &payloads);
    for j in 0..3 {
        assert_eq!(&reference[j][..], out.row(j));
    }
}

#[test]
fn scheme_decode_predictions_share_reply_or_block_storage() {
    // The zero-copy contract end to end at the scheme layer: ApproxIFER
    // predictions are rows of ONE output block; uncoded predictions are
    // the reply buffers themselves.
    let metrics = ServingMetrics::new();
    let pool = BlockPool::new();
    let k = 3;
    let d = 9;
    let queries: Vec<Vec<f32>> =
        (0..k).map(|j| (0..d).map(|t| ((j + t) as f32 * 0.2).sin()).collect()).collect();
    let qrefs: Vec<&[f32]> = queries.iter().map(|q| &q[..]).collect();
    let block = GroupBlock::from_rows(&qrefs);

    let un = Uncoded::new(k);
    let mut staged = pool.take(k, d);
    un.encode_into(&block, &mut staged);
    let coded = staged.freeze();
    let replies: Vec<Option<RowView>> = coded.row_views().into_iter().map(Some).collect();
    let out = un.decode(&replies, VerifyPolicy::off(), &metrics, &pool).unwrap();
    for (i, pred) in out.predictions.iter().enumerate() {
        assert_eq!(
            pred.as_slice().as_ptr(),
            replies[i].as_ref().unwrap().as_slice().as_ptr(),
            "uncoded prediction {i} was copied"
        );
    }

    let apx = ApproxIferCode::new(CodeParams::new(k, 1, 0));
    let mut staged = pool.take(ServingScheme::num_workers(&apx), d);
    ServingScheme::encode_into(&apx, &block, &mut staged);
    let coded = staged.freeze();
    let replies: Vec<Option<RowView>> = coded.row_views().into_iter().map(Some).collect();
    let out = ServingScheme::decode(&apx, &replies, VerifyPolicy::off(), &metrics, &pool)
        .unwrap();
    // Consecutive rows of one block: fixed stride d between row pointers.
    for w in out.predictions.windows(2) {
        let a = w[0].as_slice().as_ptr() as usize;
        let b = w[1].as_slice().as_ptr() as usize;
        assert_eq!(b - a, d * std::mem::size_of::<f32>(), "predictions not one block");
    }
}
