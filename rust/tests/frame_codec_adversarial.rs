//! Adversarial input sweep over the shared frame codec.
//!
//! Both wire surfaces — the client-facing serving protocol and the
//! coordinator↔worker fleet protocol — parse with the one
//! `server::frame::read_frame`, so this table hardens both at once: every
//! truncated, oversized, wrapping-length or garbage-head input must come
//! back as a clean `Err`, never a panic, a hang, or an oversized
//! allocation.

use std::io::Cursor;

use approxifer::server::{
    body_f32, read_frame, write_error, write_frame, MAX_FRAME, OP_HELLO, OP_PING, OP_PREDICT,
    OP_TASK, ST_ERR, ST_OK,
};

/// Hand-assemble a frame with full control over every field — including
/// the inconsistent ones a well-behaved writer can't produce.
fn raw_frame(frame_len: u32, head: u8, id: u64, plen: u64, body: &[u8]) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(&frame_len.to_le_bytes());
    buf.push(head);
    buf.extend_from_slice(&id.to_le_bytes());
    buf.extend_from_slice(&plen.to_le_bytes());
    buf.extend_from_slice(body);
    buf
}

/// `frame_len` for a consistent frame carrying `body_len` payload bytes.
fn flen(body_len: usize) -> u32 {
    17 + body_len as u32
}

#[test]
fn legitimate_frames_roundtrip_for_every_head() {
    // Float-payload heads: client query, worker dispatch, success reply.
    for head in [OP_PREDICT, OP_TASK, ST_OK] {
        let payload = [1.5f32, -2.0, 0.25];
        let mut buf = Vec::new();
        write_frame(&mut buf, head, 42, &payload).unwrap();
        let f = read_frame(&mut Cursor::new(buf)).unwrap();
        assert_eq!(f.head, head);
        assert_eq!(f.id, 42);
        assert_eq!(body_f32(&f.body), payload);
    }
    // Payload-less heads: liveness probe / heartbeat, worker join.
    for head in [OP_PING, OP_HELLO] {
        let mut buf = Vec::new();
        write_frame(&mut buf, head, 7, &[]).unwrap();
        let f = read_frame(&mut Cursor::new(buf)).unwrap();
        assert_eq!(f.head, head);
        assert_eq!(f.id, 7);
        assert!(f.body.is_empty());
    }
    // Byte-payload head: error reply.
    let mut buf = Vec::new();
    write_error(&mut buf, 9, "worker 3: injected fault").unwrap();
    let f = read_frame(&mut Cursor::new(buf)).unwrap();
    assert_eq!(f.head, ST_ERR);
    assert_eq!(f.id, 9);
    assert_eq!(std::str::from_utf8(&f.body).unwrap(), "worker 3: injected fault");

    // An empty success reply (the ping/hello ack) also roundtrips.
    let mut buf = Vec::new();
    write_frame(&mut buf, ST_OK, 0, &[]).unwrap();
    let f = read_frame(&mut Cursor::new(buf)).unwrap();
    assert_eq!(f.head, ST_OK);
    assert!(f.body.is_empty());
}

#[test]
fn malformed_frames_are_clean_protocol_errors() {
    // The value whose `* 4` wraps to exactly 8 in release builds: if the
    // length check used unchecked multiplication, this frame would pass
    // validation with a 2^62-float declared payload over an 8-byte body.
    let wrap8 = (1u64 << 62) + 2;

    let cases: Vec<(&str, Vec<u8>)> = vec![
        // --- frame_len bounds ---
        ("frame_len zero", raw_frame(0, OP_PREDICT, 1, 0, &[])),
        ("frame_len below header", raw_frame(16, OP_PREDICT, 1, 0, &[])),
        ("frame_len above MAX_FRAME", raw_frame(MAX_FRAME + 1, OP_PREDICT, 1, 0, &[])),
        ("frame_len u32::MAX", raw_frame(u32::MAX, OP_PREDICT, 1, 0, &[])),
        // --- truncation at every interesting offset ---
        ("empty input", Vec::new()),
        ("truncated length prefix", vec![0x11, 0x00]),
        ("length only, no body", flen(0).to_le_bytes().to_vec()),
        ("body shorter than declared", {
            let mut b = raw_frame(flen(8), OP_PREDICT, 1, 2, &[0u8; 8]);
            b.truncate(b.len() - 5);
            b
        }),
        ("header itself truncated", {
            let mut b = raw_frame(flen(0), OP_PING, 1, 0, &[]);
            b.truncate(9);
            b
        }),
        // --- wrapping / oversized payload_len on every float head ---
        ("wrapping payload_len on PREDICT", raw_frame(flen(8), OP_PREDICT, 1, wrap8, &[0u8; 8])),
        ("wrapping payload_len on TASK", raw_frame(flen(8), OP_TASK, 1, wrap8, &[0u8; 8])),
        ("wrapping payload_len on OK", raw_frame(flen(8), ST_OK, 1, wrap8, &[0u8; 8])),
        ("payload_len u64::MAX", raw_frame(flen(8), OP_PREDICT, 1, u64::MAX, &[0u8; 8])),
        // --- plain payload_len / body disagreements ---
        ("declared floats exceed body", raw_frame(flen(8), OP_PREDICT, 1, 3, &[0u8; 8])),
        ("declared floats undershoot body", raw_frame(flen(8), OP_TASK, 1, 1, &[0u8; 8])),
        ("non-multiple-of-4 float body", raw_frame(flen(7), ST_OK, 1, 2, &[0u8; 7])),
        ("error byte count mismatch", raw_frame(flen(3), ST_ERR, 1, 5, b"abc")),
        // --- payload smuggled onto payload-less ops ---
        ("payload on PING", raw_frame(flen(4), OP_PING, 1, 1, &[0u8; 4])),
        ("payload on HELLO", raw_frame(flen(4), OP_HELLO, 1, 1, &[0u8; 4])),
        ("declared-but-absent payload on PING", raw_frame(flen(0), OP_PING, 1, 9, &[])),
        // --- garbage head bytes ---
        ("head 0", raw_frame(flen(0), 0, 1, 0, &[])),
        ("head 5 (past the op space)", raw_frame(flen(0), 5, 1, 0, &[])),
        ("head 200", raw_frame(flen(4), 200, 1, 1, &[0u8; 4])),
    ];

    for (name, bytes) in cases {
        let res = read_frame(&mut Cursor::new(bytes));
        assert!(res.is_err(), "{name}: expected a protocol error, got a parsed frame");
    }
}

#[test]
fn error_messages_identify_the_violation() {
    // Spot-check that the three distinct failure classes are
    // distinguishable in the error text (operators grep these).
    let wrap = raw_frame(flen(8), OP_PREDICT, 1, (1u64 << 62) + 2, &[0u8; 8]);
    let err = read_frame(&mut Cursor::new(wrap)).unwrap_err();
    assert!(format!("{err:#}").contains("payload length mismatch"), "{err:#}");

    let huge = raw_frame(MAX_FRAME + 1, OP_PREDICT, 1, 0, &[]);
    let err = read_frame(&mut Cursor::new(huge)).unwrap_err();
    assert!(format!("{err:#}").contains("bad frame length"), "{err:#}");

    let garbage = raw_frame(flen(0), 99, 1, 0, &[]);
    let err = read_frame(&mut Cursor::new(garbage)).unwrap_err();
    assert!(format!("{err:#}").contains("unknown frame head"), "{err:#}");
}
