//! Integration: the concurrent multi-group scheduler. Seeded and
//! deterministic — fault placement comes from the `fault_hook`, not a
//! clock race. The headline property: stragglers injected into group *g*
//! must not head-of-line block groups *g+1..g+3*.

use std::sync::Arc;
use std::time::{Duration, Instant};

use approxifer::coding::{ApproxIferCode, CodeParams, ServingScheme};
use approxifer::coordinator::{FaultPlan, PredictionHandle, Service};
use approxifer::workers::{ByzantineMode, DelayMockEngine, InferenceEngine, LinearMockEngine};

fn payload(j: usize, d: usize) -> Vec<f32> {
    (0..d).map(|t| ((j as f32) * 0.27 + (t as f32) * 0.019).sin()).collect()
}

fn approxifer(k: usize, s: usize, e: usize) -> Arc<dyn ServingScheme> {
    Arc::new(ApproxIferCode::new(CodeParams::new(k, s, e)))
}

#[test]
fn straggled_group_does_not_block_later_groups() {
    // K=3, S=1 → 4 workers, decoder waits for the fastest 3 replies.
    // Group 1 gets S+1 = 2 forced stragglers (replies held 2s), so it
    // cannot complete before ~2s. Groups 2..4 are fault-free and must
    // complete well within 1s — the serial coordinator would hold them
    // behind group 1's collect wait. (The 1s margin over ~ms of actual
    // work derisks loaded CI runners.)
    let engine = Arc::new(LinearMockEngine::new(8, 4));
    let svc = Service::builder(approxifer(3, 1, 0))
        .engine(engine.clone())
        .max_inflight(4)
        .decode_threads(2)
        .seed(7)
        .fault_hook(Arc::new(|group| {
            if group == 1 {
                FaultPlan {
                    stragglers: vec![0, 1],
                    straggler_delay: Duration::from_secs(2),
                    ..FaultPlan::none()
                }
            } else {
                FaultPlan::none()
            }
        }))
        .spawn()
        .unwrap();
    let t0 = Instant::now();
    // 12 queries = exactly 4 full K=3 groups, formed in submission order.
    let handles: Vec<PredictionHandle> = (0..12).map(|j| svc.submit(payload(j, 8))).collect();
    let mut handles: Vec<Option<PredictionHandle>> = handles.into_iter().map(Some).collect();
    // Groups 2..4 (queries 3..12) first: must resolve fast.
    for (j, slot) in handles.iter_mut().enumerate().skip(3) {
        let h = slot.take().unwrap();
        let pred = h.wait_timeout(Duration::from_secs(5)).unwrap();
        let want = engine.infer1(&payload(j, 8)).unwrap();
        for t in 0..4 {
            assert!((pred[t] - want[t]).abs() < 0.3, "q{j} c{t}");
        }
    }
    let later_done = t0.elapsed();
    assert!(
        later_done < Duration::from_secs(1),
        "groups 2..4 blocked behind straggled group 1: {later_done:?}"
    );
    // Group 1 still completes (one straggler is ridden out, the second
    // arrives at ~2s and fills the wait count).
    for (j, slot) in handles.iter_mut().enumerate().take(3) {
        let h = slot.take().unwrap();
        let pred = h.wait_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(pred.len(), 4, "q{j}");
    }
    assert_eq!(svc.metrics.groups_decoded.get(), 4);
    svc.shutdown();
}

#[test]
fn max_inflight_cap_is_enforced() {
    // Slow engine (20ms/query) + max_inflight=2 + 6 instant groups: the
    // batcher must block at least once on the inflight gate, and still
    // answer everything.
    let engine: Arc<dyn InferenceEngine> =
        Arc::new(DelayMockEngine::new(6, 2, Duration::from_millis(20)));
    let svc = Service::builder(approxifer(1, 1, 0)) // 2 workers
        .engine(engine)
        .max_inflight(2)
        .decode_threads(1)
        .flush_after(Duration::from_millis(1))
        .spawn()
        .unwrap();
    let handles: Vec<PredictionHandle> = (0..6).map(|j| svc.submit(payload(j, 6))).collect();
    for h in handles {
        h.wait_timeout(Duration::from_secs(10)).unwrap();
    }
    assert_eq!(svc.metrics.groups_decoded.get(), 6);
    assert!(
        svc.metrics.inflight_full_waits.get() > 0,
        "6 slow groups at max_inflight=2 should have hit the gate"
    );
    svc.shutdown();
}

#[test]
fn byzantine_location_works_under_concurrency() {
    // Deterministic adversary: worker 2 corrupts every group. Four groups
    // in flight; every decode must flag it and stay near the reference.
    let engine = Arc::new(LinearMockEngine::new(10, 6));
    let svc = Service::builder(approxifer(3, 0, 1))
        .engine(engine.clone())
        .max_inflight(4)
        .decode_threads(2)
        .fault_hook(Arc::new(|_group| FaultPlan {
            byzantine: vec![2],
            byz_mode: Some(ByzantineMode::GaussianNoise { sigma: 20.0 }),
            ..FaultPlan::none()
        }))
        .spawn()
        .unwrap();
    let handles: Vec<PredictionHandle> = (0..12).map(|j| svc.submit(payload(j, 10))).collect();
    for (j, h) in handles.into_iter().enumerate() {
        let pred = h.wait_timeout(Duration::from_secs(10)).unwrap();
        let want = engine.infer1(&payload(j, 10)).unwrap();
        for t in 0..6 {
            assert!(
                (pred[t] - want[t]).abs() < 1.0,
                "q{j} c{t}: {} vs {}",
                pred[t],
                want[t]
            );
        }
    }
    assert_eq!(svc.metrics.groups_decoded.get(), 4);
    assert!(svc.metrics.byzantine_flagged.get() >= 4, "adversary flagged every group");
    svc.shutdown();
}

#[test]
fn sustained_open_loop_overlap_decodes_everything() {
    // A flood of 20 groups through a 4-deep pipeline with per-task tail
    // latency: everything must decode exactly once (no lost or duplicated
    // replies under reordering).
    use approxifer::sim::{run_scenario, Arrivals};
    use approxifer::workers::LatencyModel;
    let engine = Arc::new(LinearMockEngine::new(8, 3));
    let svc = Arc::new(
        Service::builder(approxifer(4, 1, 0))
            .engine(engine)
            .flush_after(Duration::from_millis(2))
            .max_inflight(4)
            .worker_latency(LatencyModel::Bimodal { base_ms: 0.5, straggler_ms: 15.0, p: 0.15 })
            .spawn()
            .unwrap(),
    );
    let report =
        run_scenario(&svc, 8, 80, Arrivals::Bursty { burst: 80, period_ms: 0.0 }, 11).unwrap();
    assert_eq!(report.completed, 80);
    assert_eq!(report.failed, 0);
    assert_eq!(svc.metrics.groups_decoded.get(), 20);
    assert_eq!(svc.metrics.queries_received.get(), 80);
}
