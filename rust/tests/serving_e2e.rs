//! Integration: online service + TCP server over mock engines — the whole
//! L3 stack minus PJRT. No artifacts required.

use std::io::{Read, Write};
use std::sync::Arc;
use std::time::Duration;

use approxifer::coding::{ApproxIferCode, CodeParams, ServingScheme};
use approxifer::coordinator::{Service, VerifyPolicy};
use approxifer::server::{Client, Server};
use approxifer::sim::faults::{Behavior, FaultProfile};
use approxifer::sim::{run_scenario, Arrivals};
use approxifer::workers::{InferenceEngine, LatencyModel, LinearMockEngine, WorkerSpec};

fn approxifer(k: usize, s: usize, e: usize) -> Arc<dyn ServingScheme> {
    Arc::new(ApproxIferCode::new(CodeParams::new(k, s, e)))
}

fn service(
    k: usize,
    s: usize,
    e: usize,
    d: usize,
    c: usize,
) -> (Arc<Service>, Arc<LinearMockEngine>) {
    let engine = Arc::new(LinearMockEngine::new(d, c));
    let svc = Service::builder(approxifer(k, s, e))
        .engine(engine.clone())
        .flush_after(Duration::from_millis(10))
        .spawn()
        .unwrap();
    (Arc::new(svc), engine)
}

#[test]
fn tcp_roundtrip_approximates_reference() {
    let (svc, engine) = service(4, 1, 0, 16, 5);
    let server = Server::start("127.0.0.1:0", svc.clone(), 16).unwrap();
    let addr = server.addr();
    let mut clients: Vec<_> = (0..4).map(|_| Client::connect(&addr).unwrap()).collect();
    // Four queries from four connections fill exactly one group.
    let queries: Vec<Vec<f32>> = (0..4)
        .map(|j| (0..16).map(|t| ((j as f32) * 0.4 + (t as f32) * 0.05).sin()).collect())
        .collect();
    let mut joins = Vec::new();
    for (mut cl, q) in clients.drain(..).zip(queries.clone()) {
        joins.push(std::thread::spawn(move || cl.predict(&q).unwrap()));
    }
    for (j, (join, q)) in joins.into_iter().zip(&queries).enumerate() {
        let pred = join.join().unwrap();
        let want = engine.infer1(q).unwrap();
        for t in 0..5 {
            assert!(
                (pred[t] - want[t]).abs() < 0.3,
                "q{j} c{t}: {} vs {}",
                pred[t],
                want[t]
            );
        }
    }
    server.shutdown();
}

#[test]
fn scenario_under_straggler_tail_completes() {
    let engine = Arc::new(LinearMockEngine::new(8, 3));
    let scheme = approxifer(4, 1, 0);
    let nw = scheme.num_workers();
    let svc = Arc::new(
        Service::builder(scheme)
            .engine(engine)
            .flush_after(Duration::from_millis(5))
            .workers(vec![
                WorkerSpec::new(LatencyModel::Bimodal {
                    base_ms: 0.5,
                    straggler_ms: 40.0,
                    p: 0.1
                });
                nw
            ])
            .spawn()
            .unwrap(),
    );
    let report = run_scenario(&svc, 8, 64, Arrivals::Poisson { rate: 500.0 }, 3).unwrap();
    assert_eq!(report.completed, 64);
    assert_eq!(report.failed, 0);
    // The tail is ridden out: p50 well under the 40ms straggler delay.
    assert!(report.latency.p50 < 0.06, "p50={}", report.latency.p50);
}

#[test]
fn byzantine_service_keeps_answering() {
    // One Gaussian-noise adversary (behavior program, not a per-group
    // plan) with decode verification on: every group must still answer,
    // the adversary must be flagged, and verification must hold up.
    let engine = Arc::new(LinearMockEngine::new(8, 6));
    let scheme = approxifer(3, 0, 1);
    let profile = FaultProfile::parse("byz-random:1:20", scheme.num_workers(), 0xA11CE).unwrap();
    let svc = Arc::new(
        Service::builder(scheme)
            .engine(engine)
            .flush_after(Duration::from_millis(5))
            .verify(VerifyPolicy::on(0.4))
            .fault_profile(profile)
            .spawn()
            .unwrap(),
    );
    let report = run_scenario(&svc, 8, 30, Arrivals::Uniform { rate: 300.0 }, 4).unwrap();
    assert_eq!(report.completed, 30);
    assert!(svc.metrics.byzantine_flagged.get() > 0, "no adversaries flagged");
    assert!(svc.metrics.corrupt_replies_injected.get() > 0, "injection never fired");
    assert!(svc.metrics.locator_hits.get() > 0, "verification never confirmed a locate");
    assert_eq!(svc.metrics.redispatches.get(), 0, "clean groups must not redispatch");
}

// ---- raw wire-protocol helpers (the documented frame layout, rebuilt
// here so the test exercises the format independently of the server's own
// codec): u32 frame_len | u8 head | u64 id | u64 payload_len | body.

const OP_PREDICT: u8 = 1;
const ST_OK: u8 = 16;

fn send_predict(stream: &mut std::net::TcpStream, id: u64, payload: &[f32]) {
    let mut buf = Vec::new();
    buf.extend_from_slice(&((1 + 8 + 8 + payload.len() * 4) as u32).to_le_bytes());
    buf.push(OP_PREDICT);
    buf.extend_from_slice(&id.to_le_bytes());
    buf.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    for &x in payload {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    stream.write_all(&buf).unwrap();
    stream.flush().unwrap();
}

fn recv_response(stream: &mut std::net::TcpStream) -> (u8, u64, Vec<f32>) {
    let mut len4 = [0u8; 4];
    stream.read_exact(&mut len4).unwrap();
    let len = u32::from_le_bytes(len4) as usize;
    let mut frame = vec![0u8; len];
    stream.read_exact(&mut frame).unwrap();
    let head = frame[0];
    let id = u64::from_le_bytes(frame[1..9].try_into().unwrap());
    let body: Vec<f32> = frame[17..]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    (head, id, body)
}

fn payload_for(id: u64, d: usize) -> Vec<f32> {
    (0..d).map(|t| ((id as f32) * 0.11 + (t as f32) * 0.023).sin()).collect()
}

#[test]
fn interleaved_request_ids_survive_slow_worker_reordering() {
    // Two raw connections pipeline interleaved request ids into a service
    // whose fleet runs a slow-worker behavior profile, with every other
    // group additionally straggled far past the fast groups. Responses
    // complete out of submission order; every reply must carry its request
    // id and the prediction for *that id's* payload (no crossed wires).
    let d = 8;
    let engine = Arc::new(LinearMockEngine::new(d, 3));
    let scheme = approxifer(2, 1, 0);
    let nw = scheme.num_workers();
    let mut profile = FaultProfile::honest(nw);
    for b in profile.behaviors.iter_mut() {
        *b = Behavior::Slow { base_ms: 0.0, tail_ms: 15.0, p: 0.5 };
    }
    use approxifer::coordinator::FaultPlan;
    let svc = Arc::new(
        Service::builder(scheme)
            .engine(engine.clone())
            .flush_after(Duration::from_millis(3))
            .max_inflight(8)
            .fault_profile(profile)
            .fault_hook(Arc::new(|group| {
                if group % 2 == 1 {
                    FaultPlan {
                        stragglers: vec![0, 1, 2],
                        straggler_delay: Duration::from_millis(80),
                        ..FaultPlan::none()
                    }
                } else {
                    FaultPlan::none()
                }
            }))
            .spawn()
            .unwrap(),
    );
    let server = Server::start("127.0.0.1:0", svc.clone(), d).unwrap();
    let addr = server.addr();

    let per_conn = 8usize;
    let mut joins = Vec::new();
    for conn in 0..2u64 {
        let engine = engine.clone();
        joins.push(std::thread::spawn(move || {
            let mut stream = std::net::TcpStream::connect(addr).unwrap();
            stream.set_nodelay(true).ok();
            let ids: Vec<u64> = (0..per_conn as u64).map(|i| 100 + conn + 2 * i).collect();
            for &id in &ids {
                send_predict(&mut stream, id, &payload_for(id, d));
            }
            let mut seen = Vec::new();
            for _ in 0..per_conn {
                let (head, id, pred) = recv_response(&mut stream);
                assert_eq!(head, ST_OK, "id {id} errored");
                assert!(ids.contains(&id), "unknown id {id} on connection {conn}");
                // The payload must be the prediction for THIS id's query.
                let want = engine.infer1(&payload_for(id, d)).unwrap();
                for t in 0..3 {
                    assert!(
                        (pred[t] - want[t]).abs() < 0.3,
                        "id {id} c{t}: {} vs {}",
                        pred[t],
                        want[t]
                    );
                }
                seen.push(id);
            }
            let mut sorted = seen.clone();
            sorted.sort_unstable();
            let mut expect = ids.clone();
            expect.sort_unstable();
            assert_eq!(sorted, expect, "connection {conn} lost or duplicated replies");
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    assert_eq!(svc.metrics.queries_received.get(), 2 * per_conn as u64);
    server.shutdown();
}

#[test]
fn metrics_accumulate_across_groups() {
    let (svc, _e) = service(2, 1, 0, 8, 3);
    let report = run_scenario(&svc, 8, 20, Arrivals::Uniform { rate: 1e5 }, 5).unwrap();
    assert_eq!(report.completed, 20);
    assert_eq!(svc.metrics.queries_received.get(), 20);
    assert_eq!(svc.metrics.groups_decoded.get(), 10);
    assert!(svc.metrics.group_latency.count() >= 10);
}
