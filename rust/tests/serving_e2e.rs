//! Integration: online service + TCP server over mock engines — the whole
//! L3 stack minus PJRT. No artifacts required.

use std::sync::Arc;
use std::time::Duration;

use approxifer::coding::CodeParams;
use approxifer::coordinator::{Service, ServiceConfig};
use approxifer::server::{Client, Server};
use approxifer::sim::{run_scenario, Arrivals};
use approxifer::workers::{
    ByzantineMode, InferenceEngine, LatencyModel, LinearMockEngine, WorkerSpec,
};

fn service(
    k: usize,
    s: usize,
    e: usize,
    d: usize,
    c: usize,
) -> (Arc<Service>, Arc<LinearMockEngine>) {
    let engine = Arc::new(LinearMockEngine::new(d, c));
    let params = CodeParams::new(k, s, e);
    let mut cfg = ServiceConfig::new(params);
    cfg.flush_after = Duration::from_millis(10);
    (Arc::new(Service::start(engine.clone(), cfg)), engine)
}

#[test]
fn tcp_roundtrip_approximates_reference() {
    let (svc, engine) = service(4, 1, 0, 16, 5);
    let server = Server::start("127.0.0.1:0", svc.clone(), 16).unwrap();
    let addr = server.addr();
    let mut clients: Vec<_> = (0..4).map(|_| Client::connect(&addr).unwrap()).collect();
    // Four queries from four connections fill exactly one group.
    let queries: Vec<Vec<f32>> = (0..4)
        .map(|j| (0..16).map(|t| ((j as f32) * 0.4 + (t as f32) * 0.05).sin()).collect())
        .collect();
    let mut joins = Vec::new();
    for (mut cl, q) in clients.drain(..).zip(queries.clone()) {
        joins.push(std::thread::spawn(move || cl.predict(&q).unwrap()));
    }
    for (j, (join, q)) in joins.into_iter().zip(&queries).enumerate() {
        let pred = join.join().unwrap();
        let want = engine.infer1(q).unwrap();
        for t in 0..5 {
            assert!(
                (pred[t] - want[t]).abs() < 0.3,
                "q{j} c{t}: {} vs {}",
                pred[t],
                want[t]
            );
        }
    }
    server.shutdown();
}

#[test]
fn scenario_under_straggler_tail_completes() {
    let engine = Arc::new(LinearMockEngine::new(8, 3));
    let params = CodeParams::new(4, 1, 0);
    let mut cfg = ServiceConfig::new(params);
    cfg.flush_after = Duration::from_millis(5);
    cfg.worker_specs = vec![
        WorkerSpec { latency: LatencyModel::Bimodal { base_ms: 0.5, straggler_ms: 40.0, p: 0.1 } };
        params.num_workers()
    ];
    let svc = Arc::new(Service::start(engine, cfg));
    let report = run_scenario(&svc, 8, 64, Arrivals::Poisson { rate: 500.0 }, 3).unwrap();
    assert_eq!(report.completed, 64);
    assert_eq!(report.failed, 0);
    // The tail is ridden out: p50 well under the 40ms straggler delay.
    assert!(report.latency.p50 < 0.06, "p50={}", report.latency.p50);
}

#[test]
fn byzantine_service_keeps_answering() {
    let engine = Arc::new(LinearMockEngine::new(8, 6));
    let params = CodeParams::new(3, 0, 1);
    let mut cfg = ServiceConfig::new(params);
    cfg.flush_after = Duration::from_millis(5);
    cfg.byz_mode = Some(ByzantineMode::GaussianNoise { sigma: 20.0 });
    let svc = Arc::new(Service::start(engine, cfg));
    let report = run_scenario(&svc, 8, 30, Arrivals::Uniform { rate: 300.0 }, 4).unwrap();
    assert_eq!(report.completed, 30);
    assert!(svc.metrics.byzantine_flagged.get() > 0, "no adversaries flagged");
}

#[test]
fn metrics_accumulate_across_groups() {
    let (svc, _e) = service(2, 1, 0, 8, 3);
    let report = run_scenario(&svc, 8, 20, Arrivals::Uniform { rate: 1e5 }, 5).unwrap();
    assert_eq!(report.completed, 20);
    assert_eq!(svc.metrics.queries_received.get(), 20);
    assert_eq!(svc.metrics.groups_decoded.get(), 10);
    assert!(svc.metrics.group_latency.count() >= 10);
}
