"""AOT lowering smoke tests: HLO text is produced and structurally sound.

Full-model exports are exercised by ``make artifacts``; here we lower small
instances quickly and validate the interchange format (HLO *text* with a
ROOT tuple, parseable by the rust side's XLA 0.5.1 text parser).
"""

import numpy as np

from compile import aot, datasets, models


def test_lower_lenet5_batch1():
    params = models.init("lenet5", "synmnist", seed=0)
    hlo = aot.lower_model("lenet5", params, 1, datasets.shape_of("synmnist"))
    assert "HloModule" in hlo
    assert "ROOT" in hlo
    # Weights baked as constants — the ENTRY computation takes only the
    # image input (nested regions have their own parameters; look at the
    # entry layout line).
    layout = hlo.split("entry_computation_layout={(")[1].split(")->")[0]
    assert layout.count("f32[") == 1, layout
    # And the constants must actually be PRINTED (the default printer
    # elides large constants, which would strip the weights).
    lenet_params = 107786  # ~430 KB of f32 text at minimum
    assert len(hlo) > lenet_params, f"HLO text suspiciously small: {len(hlo)}"


def test_lower_encoder_contains_combine():
    hlo = aot.lower_encoder(4, 1, 0, 64)
    assert "HloModule" in hlo
    assert "ROOT" in hlo


def test_lowered_hlo_is_deterministic():
    params = models.init("lenet5", "synmnist", seed=0)
    a = aot.lower_model("lenet5", params, 1, datasets.shape_of("synmnist"))
    b = aot.lower_model("lenet5", params, 1, datasets.shape_of("synmnist"))
    assert a == b


def test_golden_export_shapes(tmp_path):
    entries = aot.export_goldens(str(tmp_path))
    assert len(entries) >= 4
    for e in entries:
        tag = e["tag"]
        for stem in ("enc_w", "queries", "coded", "avail", "decmat", "decoded"):
            p = tmp_path / "golden" / f"{stem}_{tag}.bin"
            assert p.exists(), p
            assert p.read_bytes()[:4] == b"AXT1"
    # Spot-check numerics of one golden: decoded == decmat @ coded[avail].
    def load(p):
        raw = (tmp_path / "golden" / p).read_bytes()
        ndim = np.frombuffer(raw[4:8], "<u4")[0]
        dims = np.frombuffer(raw[8 : 8 + 4 * ndim], "<u4")
        body = raw[8 + 4 * ndim :]
        if p.startswith("avail"):
            return np.frombuffer(body, "<i4").reshape(dims)
        return np.frombuffer(body, "<f4").reshape(dims)

    tag = entries[0]["tag"]
    coded = load(f"coded_{tag}.bin")
    avail = load(f"avail_{tag}.bin")
    dm = load(f"decmat_{tag}.bin")
    dec = load(f"decoded_{tag}.bin")
    np.testing.assert_allclose(dm @ coded[avail], dec, rtol=1e-4, atol=1e-5)
