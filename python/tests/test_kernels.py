"""Layer-1 Pallas kernels vs pure-jnp oracles (hypothesis shape sweeps)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import berrut as bk
from compile.kernels import matmul as mk
from compile.kernels.ref import coded_combine_ref, dense_ref, matmul_ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# ----------------------------------------------------------------- matmul --

@given(
    m=st.integers(1, 150),
    k=st.integers(1, 150),
    n=st.integers(1, 150),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_shape_sweep(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, k)).astype(np.float32)
    b = rng.normal(size=(k, n)).astype(np.float32)
    out = np.asarray(mk.matmul(jnp.asarray(a), jnp.asarray(b)))
    ref = np.asarray(matmul_ref(jnp.asarray(a), jnp.asarray(b)))
    # f32 accumulation order differs between the tiled contraction loop and
    # the single dot; tolerance scales with contraction depth.
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=1e-4 * max(1, k) ** 0.5)


@pytest.mark.parametrize("dtype", [np.float32, np.float64, np.int32])
def test_matmul_dtype_coercion(dtype):
    a = np.ones((4, 4), dtype=dtype)
    b = np.ones((4, 4), dtype=dtype)
    out = np.asarray(mk.matmul(jnp.asarray(a), jnp.asarray(b)))
    assert out.dtype == np.float32
    np.testing.assert_allclose(out, 4.0 * np.ones((4, 4)))


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        mk.matmul(jnp.zeros((2, 3)), jnp.zeros((4, 5)))
    with pytest.raises(ValueError):
        mk.matmul(jnp.zeros((2,)), jnp.zeros((2, 2)))


@given(
    bm=st.sampled_from([8, 32, 128]),
    bn=st.sampled_from([8, 32, 128]),
    bk_=st.sampled_from([8, 32, 128]),
)
def test_matmul_block_shape_invariance(bm, bn, bk_):
    """Result must not depend on the tiling — the schedule is semantics-free."""
    rng = np.random.default_rng(7)
    a = rng.normal(size=(65, 70)).astype(np.float32)
    b = rng.normal(size=(70, 33)).astype(np.float32)
    out = np.asarray(mk.matmul(jnp.asarray(a), jnp.asarray(b), bm=bm, bn=bn, bk=bk_))
    ref = np.asarray(matmul_ref(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_dense_matches_ref():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(16, 24)).astype(np.float32)
    w = rng.normal(size=(24, 10)).astype(np.float32)
    b = rng.normal(size=(10,)).astype(np.float32)
    out = np.asarray(mk.dense(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b)))
    ref = np.asarray(dense_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b)))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_vmem_budget_structural():
    """MXU-aligned default tiles fit comfortably in a 16 MiB VMEM."""
    assert mk.mxu_aligned()
    assert mk.vmem_bytes() <= 16 * 2**20 // 4  # 3 tiles of 64 KiB each


# ---------------------------------------------------------- coded combine --

@given(
    k=st.integers(2, 16),
    s=st.integers(1, 3),
    e=st.integers(0, 3),
    d=st.integers(1, 2000),
    seed=st.integers(0, 2**31 - 1),
)
def test_coded_combine_matches_ref(k, s, e, d, seed):
    w = bk.encode_matrix(k, s, e)
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(k, d)).astype(np.float32)
    out = np.asarray(bk.coded_combine(jnp.asarray(w), jnp.asarray(x)))
    ref = np.asarray(coded_combine_ref(jnp.asarray(w), jnp.asarray(x)))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_coded_combine_rejects_bad_shapes():
    with pytest.raises(ValueError):
        bk.coded_combine(jnp.zeros((3, 4)), jnp.zeros((5, 6)))


# -------------------------------------------------- encode/decode matrices --

@given(k=st.integers(1, 16), s=st.integers(1, 4), e=st.integers(0, 3))
def test_encode_matrix_partition_of_unity(k, s, e):
    w = bk.encode_matrix(k, s, e)
    n = (k + s - 1) if e == 0 else (2 * (k + e) + s - 1)
    assert w.shape == (n + 1, k)
    np.testing.assert_allclose(w.sum(axis=1), 1.0, atol=1e-5)


def test_chebyshev_points_match_paper():
    a = bk.chebyshev_first(2)
    np.testing.assert_allclose(a, [np.cos(np.pi / 4), np.cos(3 * np.pi / 4)])
    b = bk.chebyshev_second(4)
    np.testing.assert_allclose(b[0], 1.0)
    np.testing.assert_allclose(b[-1], -1.0)
    np.testing.assert_allclose(b[2], 0.0, atol=1e-16)


@given(k=st.integers(2, 12), s=st.integers(1, 3), seed=st.integers(0, 10**6))
def test_decode_matrix_rows_sum_to_one(k, s, seed):
    n = k + s - 1
    rng = np.random.default_rng(seed)
    avail = np.sort(rng.choice(n + 1, size=k, replace=False))
    d = bk.decode_matrix(k, s, 0, avail)
    assert d.shape == (k, k)
    # f32 cancellation scales with the row's weight mass (ill-conditioned
    # subsets have large +/- weights).
    leb = np.abs(d).sum(axis=1)
    np.testing.assert_allclose(d.sum(axis=1), 1.0, atol=2e-4 * np.maximum(1.0, leb).max())


def test_decode_interpolatory_when_alpha_hits_beta():
    """K=2,S=3 makes beta_1 == alpha_0 exactly: the decode weight row must be
    the unit vector at that node (the guard path)."""
    k, s = 2, 3
    avail = np.array([0, 1])
    d = bk.decode_matrix(k, s, 0, avail)
    # alpha_0 = cos(pi/4) == beta_1 = cos(pi/4).
    np.testing.assert_allclose(d[0], [0.0, 1.0], atol=1e-12)


def test_berrut_weights_guard_at_node():
    nodes = bk.chebyshev_second(5)
    w = bk.berrut_weights(nodes, float(nodes[2]))
    expect = np.zeros(6)
    expect[2] = 1.0
    np.testing.assert_allclose(w, expect)
