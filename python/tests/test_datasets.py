"""Dataset generator tests: determinism, shapes, separability, export format."""

import os
import tempfile

import numpy as np
import pytest

from compile import datasets


@pytest.mark.parametrize("name", datasets.DATASETS)
def test_shapes_and_ranges(name):
    h, w, c = datasets.shape_of(name)
    images, labels = datasets.generate(name, "test", 64)
    assert images.shape == (64, h, w, c)
    assert images.dtype == np.float32
    assert labels.shape == (64,)
    assert labels.dtype == np.int32
    assert labels.min() >= 0 and labels.max() < datasets.NUM_CLASSES
    assert np.isfinite(images).all()
    assert images.min() >= -0.5 - 1e-6 and images.max() <= 1.6 + 1e-6


@pytest.mark.parametrize("name", datasets.DATASETS)
def test_deterministic_across_calls(name):
    a_img, a_lab = datasets.generate(name, "test", 32)
    b_img, b_lab = datasets.generate(name, "test", 32)
    np.testing.assert_array_equal(a_img, b_img)
    np.testing.assert_array_equal(a_lab, b_lab)


def test_train_and_test_streams_differ():
    a_img, _ = datasets.generate("synmnist", "train", 32)
    b_img, _ = datasets.generate("synmnist", "test", 32)
    assert not np.array_equal(a_img, b_img)


def test_templates_are_class_distinct():
    for name in datasets.DATASETS:
        temps = [datasets.class_template(name, c) for c in range(10)]
        for i in range(10):
            for j in range(i + 1, 10):
                diff = np.abs(temps[i] - temps[j]).mean()
                assert diff > 0.01, f"{name}: classes {i},{j} too similar ({diff})"


def test_templates_deterministic():
    a = datasets.class_template("syncifar", 3)
    b = datasets._class_template("syncifar", 3)  # bypass cache
    np.testing.assert_array_equal(a, b)


def test_export_binary_roundtrip_f32():
    arr = np.arange(24, dtype=np.float32).reshape(2, 3, 4)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.bin")
        datasets.export_binary(path, arr)
        with open(path, "rb") as f:
            assert f.read(4) == b"AXT1"
            ndim = np.frombuffer(f.read(4), "<u4")[0]
            assert ndim == 3
            dims = np.frombuffer(f.read(12), "<u4")
            assert tuple(dims) == (2, 3, 4)
            data = np.frombuffer(f.read(), "<f4").reshape(2, 3, 4)
            np.testing.assert_array_equal(data, arr)


def test_export_binary_rejects_unknown_dtype():
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(ValueError):
            datasets.export_binary(os.path.join(d, "t.bin"), np.zeros(3, dtype=np.float64))


def test_nearest_template_classifier_beats_chance():
    """The generator must be learnable: a trivial nearest-template classifier
    should already beat chance by a wide margin (the CNNs then do better)."""
    for name in datasets.DATASETS:
        images, labels = datasets.generate(name, "test", 200)
        temps = np.stack([datasets.class_template(name, c) for c in range(10)])
        t_flat = temps.reshape(10, -1)
        x_flat = images.reshape(len(images), -1)
        # Cosine similarity against each template.
        t_norm = t_flat / (np.linalg.norm(t_flat, axis=1, keepdims=True) + 1e-9)
        x_norm = x_flat / (np.linalg.norm(x_flat, axis=1, keepdims=True) + 1e-9)
        pred = (x_norm @ t_norm.T).argmax(1)
        acc = (pred == labels).mean()
        assert acc > 0.4, f"{name}: nearest-template acc {acc}"
