"""Numpy-level numerics of the full ApproxIFER code path (mirrors the rust
implementation; the golden vectors exported by aot.py tie the two)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import berrut as bk

settings.register_profile("ci2", max_examples=25, deadline=None)
settings.load_profile("ci2")


@given(k=st.integers(2, 12), s=st.integers(1, 3))
def test_encode_decode_identity_function_shrinks_with_subset_quality(k, s):
    """With f = id and ALL the first K workers available (drop the last S),
    decode must approximate the original queries with bounded error."""
    n = k + s - 1
    w = bk.encode_matrix(k, s, 0)
    rng = np.random.default_rng(k * 100 + s)
    # Smooth query family (what Berrut approximates well).
    alpha = bk.chebyshev_first(k)
    x = np.stack([np.sin(2 * alpha) + 0.3 * alpha, np.cos(alpha)], axis=1).astype(np.float32)
    coded = w @ x
    avail = np.arange(k)
    d = bk.decode_matrix(k, s, 0, avail)
    decoded = d @ coded[avail]
    err = np.abs(decoded - x).max()
    leb = np.abs(d).sum(axis=1).max()
    # Berrut is O(h)-accurate, not exact; the subset's conditioning (leb)
    # scales the attainable error.
    assert err <= max(1.0, 1.5 * leb), f"err={err} leb={leb}"


@given(k=st.integers(2, 10), s=st.integers(1, 3), seed=st.integers(0, 10**6))
def test_decode_constant_exact(k, s, seed):
    n = k + s - 1
    rng = np.random.default_rng(seed)
    avail = np.sort(rng.choice(n + 1, size=k, replace=False))
    d = bk.decode_matrix(k, s, 0, avail)
    const = np.full((k, 5), 3.25, dtype=np.float32)
    out = d @ const
    leb = np.abs(d).sum(axis=1).max()
    np.testing.assert_allclose(out, 3.25, atol=1e-4 * max(leb, 1.0))


def test_worker_count_formulas():
    assert bk.encode_matrix(10, 1, 0).shape[0] == 11       # K+S
    assert bk.encode_matrix(12, 0, 2).shape[0] == 28       # 2(K+E)
    assert bk.encode_matrix(12, 1, 3).shape[0] == 31       # 2(K+E)+S


@given(k=st.integers(2, 8))
def test_encoded_queries_interpolate_originals(k):
    """u(alpha_j) = X_j exactly: encoding evaluated AT the query nodes must
    return the queries (the interpolant passes through them)."""
    alpha = bk.chebyshev_first(k)
    rng = np.random.default_rng(k)
    x = rng.normal(size=(k, 7)).astype(np.float32)
    for j in range(k):
        wj = bk.berrut_weights(alpha, float(alpha[j]))
        rec = wj @ x
        np.testing.assert_allclose(rec, x[j], atol=1e-6)


def test_signs_keyed_to_worker_indices_in_decode():
    """Dropping a worker must keep (-1)^i of the survivors unchanged."""
    k, s = 4, 2
    n = k + s - 1
    beta = bk.chebyshev_second(n)
    avail = np.array([0, 2, 3, 5])
    d = bk.decode_matrix(k, s, 0, avail)
    alpha = bk.chebyshev_first(k)
    # Manual eq. (10) at alpha_0.
    raw = ((-1.0) ** (avail % 2)) / (alpha[0] - beta[avail])
    manual = raw / raw.sum()
    np.testing.assert_allclose(d[0], manual.astype(np.float32), atol=1e-6)
