"""Layer-2 model zoo tests: shapes, pallas-head equivalence, serialization."""

import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from compile import datasets, models


@pytest.mark.parametrize("arch", models.ARCHS)
@pytest.mark.parametrize("dataset", ["synmnist", "syncifar"])
def test_apply_shapes(arch, dataset):
    h, w, c = datasets.shape_of(dataset)
    params = models.init(arch, dataset, seed=1)
    x = jnp.zeros((3, h, w, c), jnp.float32)
    out = models.apply(arch, params, x)
    assert out.shape == (3, 10)
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("arch", models.ARCHS)
def test_pallas_head_matches_jnp_head(arch):
    """use_pallas=True must be numerically identical (the AOT path runs the
    L1 kernel; training ran plain jnp)."""
    params = models.init(arch, "syncifar", seed=2)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 32, 32, 3)).astype(np.float32))
    a = np.asarray(models.apply(arch, params, x, use_pallas=False))
    b = np.asarray(models.apply(arch, params, x, use_pallas=True))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_init_is_deterministic():
    a = models.init("resnet18_s", "syncifar", seed=3)
    b = models.init("resnet18_s", "syncifar", seed=3)
    fa, fb = models._flatten(a), models._flatten(b)
    assert [n for n, _ in fa] == [n for n, _ in fb]
    for (_, x), (_, y) in zip(fa, fb):
        np.testing.assert_array_equal(x, y)


def test_different_seeds_differ():
    a = models.init("lenet5", "synmnist", seed=1)
    b = models.init("lenet5", "synmnist", seed=2)
    assert not np.array_equal(np.asarray(a["c1"]["w"]), np.asarray(b["c1"]["w"]))


@pytest.mark.parametrize("arch", ["lenet5", "googlenet_s"])
def test_params_save_load_roundtrip(arch):
    params = models.init(arch, "syncifar", seed=4)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "p.axp")
        models.save_params(path, params)
        loaded = models.load_params(path)
    fa, fb = models._flatten(params), models._flatten(loaded)
    assert [n for n, _ in fa] == [n for n, _ in fb]
    for (_, x), (_, y) in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # Behaviourally identical too.
    x = jnp.asarray(np.random.default_rng(5).normal(size=(2, 32, 32, 3)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(models.apply(arch, params, x)),
        np.asarray(models.apply(arch, loaded, x)),
        rtol=1e-6,
    )


def test_param_count_positive_and_stable():
    counts = {arch: models.param_count(models.init(arch, "syncifar", 0)) for arch in models.ARCHS}
    for arch, n in counts.items():
        assert n > 1000, f"{arch}: {n}"
    # Family ordering sanity: resnet34_s deeper than resnet18_s.
    assert counts["resnet34_s"] > counts["resnet18_s"]


def test_batch_independence():
    """Per-sample outputs must not depend on batch composition."""
    params = models.init("resnet18_s", "syncifar", seed=6)
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(4, 32, 32, 3)).astype(np.float32))
    full = np.asarray(models.apply("resnet18_s", params, x))
    single = np.asarray(models.apply("resnet18_s", params, x[1:2]))
    np.testing.assert_allclose(full[1:2], single, rtol=1e-4, atol=1e-5)
