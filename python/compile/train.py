"""Build-time training for the hosted models.

Runs ONCE under ``make artifacts`` (never at serving time): trains each
(architecture, dataset) pair of DESIGN.md's experiment plan with SGD +
momentum on the synthetic datasets, reports test accuracy (the paper's
"base model / best case" line), and saves the parameters for aot.py to
bake into the HLO artifacts.

The training loop is deliberately simple (no BN state, no augmentation
beyond the generator's jitter) — the goal is a well-trained nonlinear
classifier per architecture, not SOTA.
"""

from __future__ import annotations

import functools
import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets, models

# (arch, dataset) pairs required by the figures (DESIGN.md §5):
# resnet18_s on all three datasets (figs 3, 5-7, 9, 11); the architecture
# sweep on syncifar (figs 8, 10).
PLAN: Tuple[Tuple[str, str], ...] = (
    ("resnet18_s", "synmnist"),
    ("resnet18_s", "synfashion"),
    ("resnet18_s", "syncifar"),
    ("lenet5", "syncifar"),
    ("vgg_s", "syncifar"),
    ("resnet34_s", "syncifar"),
    ("densenet_s", "syncifar"),
    ("googlenet_s", "syncifar"),
)

TRAIN_N, TEST_N = 4096, 1024
BATCH, EPOCHS, LR = 128, 8, 1e-3
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8
# Mixup (Beta(1,1) pair mixing on half the steps): the hosted models must
# behave reasonably on *blended* inputs because ApproxIFER's coded queries
# are (signed) linear combinations of real queries. Off-the-shelf natural-
# image models have this property emergently; on synthetic data we train it
# in explicitly. Base accuracy is unaffected; coded accuracy improves
# substantially (EXPERIMENTS.md §Deviations).
MIXUP_EVERY = 2


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logz = jax.nn.log_softmax(logits)
    return -logz[jnp.arange(labels.shape[0]), labels].mean()


@functools.partial(jax.jit, static_argnames=("arch",))
def _loss_and_grad(arch, params, x, y):
    def loss_fn(p):
        return cross_entropy(models.apply(arch, p, x, use_pallas=False), y)

    return jax.value_and_grad(loss_fn)(params)


@functools.partial(jax.jit, static_argnames=("arch",))
def _mixup_loss_and_grad(arch, params, x, ya, yb, lam):
    def loss_fn(p):
        logz = jax.nn.log_softmax(models.apply(arch, p, x, use_pallas=False))
        idx = jnp.arange(x.shape[0])
        return (lam * -logz[idx, ya] + (1.0 - lam) * -logz[idx, yb]).mean()

    return jax.value_and_grad(loss_fn)(params)


@functools.partial(jax.jit, static_argnames=("arch",))
def _accuracy_batch(arch, params, x, y):
    pred = models.apply(arch, params, x, use_pallas=False).argmax(axis=1)
    return (pred == y).mean()


def evaluate(arch: str, params, images: np.ndarray, labels: np.ndarray,
             batch: int = 256) -> float:
    correct = 0.0
    for i in range(0, len(images), batch):
        xb = jnp.asarray(images[i : i + batch])
        yb = jnp.asarray(labels[i : i + batch])
        correct += float(_accuracy_batch(arch, params, xb, yb)) * len(xb)
    return correct / len(images)


def train_one(arch: str, dataset: str, *, epochs: int = EPOCHS,
              train_n: int = TRAIN_N, test_n: int = TEST_N,
              verbose: bool = True) -> Tuple[Dict, float]:
    """Train one model; returns (params, test_accuracy)."""
    xtr, ytr = datasets.generate(dataset, "train", train_n)
    xte, yte = datasets.generate(dataset, "test", test_n)
    params = models.init(arch, dataset, seed=17)
    # Adam state (stabler than bare SGD-momentum across the arch zoo).
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    step = 0
    rng = np.random.default_rng(7)
    t0 = time.time()
    for epoch in range(epochs):
        order = rng.permutation(train_n)
        ep_loss = 0.0
        nb = 0
        for i in range(0, train_n, BATCH):
            idx = order[i : i + BATCH]
            if (i // BATCH) % MIXUP_EVERY == 1:
                perm = rng.permutation(len(idx))
                lam = float(rng.beta(1.0, 1.0))
                x = jnp.asarray(lam * xtr[idx] + (1.0 - lam) * xtr[idx][perm])
                loss, grads = _mixup_loss_and_grad(
                    arch, params, x,
                    jnp.asarray(ytr[idx]), jnp.asarray(ytr[idx][perm]),
                    jnp.asarray(lam),
                )
            else:
                x = jnp.asarray(xtr[idx])
                y = jnp.asarray(ytr[idx])
                loss, grads = _loss_and_grad(arch, params, x, y)
            step += 1
            m = jax.tree.map(lambda mm, g: ADAM_B1 * mm + (1 - ADAM_B1) * g, m, grads)
            v = jax.tree.map(lambda vv, g: ADAM_B2 * vv + (1 - ADAM_B2) * g * g, v, grads)
            bc1 = 1 - ADAM_B1**step
            bc2 = 1 - ADAM_B2**step
            params = jax.tree.map(
                lambda p, mm, vv: p - LR * (mm / bc1) / (jnp.sqrt(vv / bc2) + ADAM_EPS),
                params, m, v,
            )
            ep_loss += float(loss)
            nb += 1
        if verbose:
            acc = evaluate(arch, params, xte[:256], yte[:256])
            print(f"  [{arch}/{dataset}] epoch {epoch+1}/{epochs} "
                  f"loss={ep_loss/nb:.4f} acc~{acc:.3f} ({time.time()-t0:.0f}s)")
    test_acc = evaluate(arch, params, xte, yte)
    if verbose:
        print(f"  [{arch}/{dataset}] final test acc {test_acc:.4f} "
              f"({models.param_count(params)} params, {time.time()-t0:.0f}s)")
    return params, test_acc
