"""Layer-1 Pallas kernel: tiled f32 matmul (the classifier-head hot spot).

TPU-style design (DESIGN.md §4 Hardware-Adaptation): the grid walks
(M/bm, N/bn, K/bk) tiles; each step pulls one (bm, bk) A-tile and one
(bk, bn) B-tile from HBM into VMEM via BlockSpec, multiply-accumulates on
the MXU into the resident (bm, bn) output tile, which is written back when
the contraction loop finishes. Block shapes default to MXU-aligned 128s
and are clamped/padded for small operands.

On this image Pallas runs with ``interpret=True`` (the CPU PJRT client
cannot execute Mosaic custom-calls), so the kernel is validated for
correctness here and its TPU efficiency is estimated structurally
(VMEM footprint / MXU alignment) in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned default tile sizes.
BM, BN, BK = 128, 128, 128


def _matmul_kernel(a_ref, b_ref, o_ref, *, n_k: int):
    """One grid step: o[bm,bn] (+)= a[bm,bk] @ b[bk,bn]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = BM,
    bn: int = BN,
    bk: int = BK,
    interpret: bool = True,
) -> jnp.ndarray:
    """Tiled Pallas GEMM: (M, K) @ (K, N) -> (M, N), f32 accumulate.

    Operands are zero-padded up to tile multiples (zero rows/columns do not
    change the product) and the result is sliced back.
    """
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"matmul shapes {a.shape} @ {b.shape}")
    m, k = a.shape
    _, n = b.shape
    # Clamp blocks for small operands, keeping lane alignment where possible.
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    bk = min(bk, _ceil_to(k, 8))
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    a_p = jnp.pad(a.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    b_p = jnp.pad(b.astype(jnp.float32), ((0, kp - k), (0, np_ - n)))
    n_k = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]


def dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, *, interpret: bool = True):
    """Classifier-head dense layer on the Pallas GEMM: x.W + b."""
    return matmul(x, w, interpret=interpret) + b


def vmem_bytes(bm: int = BM, bn: int = BN, bk: int = BK) -> int:
    """Structural VMEM footprint of one grid step (A, B, O tiles, f32)."""
    return 4 * (bm * bk + bk * bn + bm * bn)


def mxu_aligned(bm: int = BM, bn: int = BN, bk: int = BK) -> bool:
    """Whether the tile shape fills 128x128 MXU passes exactly."""
    return bm % 128 == 0 and bn % 128 == 0 and bk % 128 == 0
