"""Layer-1 Pallas kernel: Berrut coded-combine (the encoder hot spot).

Computes ``X_tilde = W @ X`` where ``W`` is the (N+1, K) Berrut encode
matrix (paper eqs. (4)-(8)) and ``X`` is the (K, D) matrix of flattened
query payloads. N+1 and K are tiny (<= ~32) while D is the payload size
(e.g. 3072 for 32x32x3), so the TPU mapping differs from the generic GEMM:
the whole coefficient matrix stays resident in VMEM and the grid walks D in
lane-aligned chunks, each step streaming one (K, bd) payload tile and
producing one (N+1, bd) coded tile — an outer-product-accumulate schedule
with W reused across the entire grid.

Also provides the numpy construction of W itself (`encode_matrix`), which
is the golden reference shared with the rust implementation
(rust/src/coding/scheme.rs) via artifacts/golden/.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Payload chunk: one VPU lane-aligned slab.
BD = 512


def chebyshev_first(k: int) -> np.ndarray:
    """alpha_j = cos((2j+1) pi / 2K), j in [K-1] (paper eq. (6))."""
    j = np.arange(k)
    return np.cos((2 * j + 1) * np.pi / (2 * k))


def chebyshev_second(n: int) -> np.ndarray:
    """beta_i = cos(i pi / N), i in [N] (paper eq. (8)); N+1 points."""
    i = np.arange(n + 1)
    return np.cos(i * np.pi / n)


def berrut_weights(nodes: np.ndarray, z: float, signs: np.ndarray | None = None) -> np.ndarray:
    """Barycentric basis l_i(z) with alternating signs (paper eq. (5))."""
    if signs is None:
        signs = np.arange(len(nodes))
    guard = np.abs(z - nodes) < 1e-12
    if guard.any():
        w = np.zeros(len(nodes))
        w[np.argmax(guard)] = 1.0
        return w
    raw = ((-1.0) ** (signs % 2)) / (z - nodes)
    return raw / raw.sum()


def encode_matrix(k: int, s: int, e: int) -> np.ndarray:
    """The (N+1, K) ApproxIFER encode matrix W[i, j] = l_j(beta_i)."""
    n = (k + s - 1) if e == 0 else (2 * (k + e) + s - 1)
    alpha = chebyshev_first(k)
    beta = chebyshev_second(n)
    return np.stack([berrut_weights(alpha, b) for b in beta]).astype(np.float32)


def decode_matrix(k: int, s: int, e: int, avail: np.ndarray) -> np.ndarray:
    """The (K, |F|) decode matrix D[j, m] = l-hat_{avail[m]}(alpha_j) with
    signs keyed to original worker indices (paper eq. (10))."""
    n = (k + s - 1) if e == 0 else (2 * (k + e) + s - 1)
    alpha = chebyshev_first(k)
    beta = chebyshev_second(n)
    nodes = beta[avail]
    return np.stack(
        [berrut_weights(nodes, a, signs=np.asarray(avail)) for a in alpha]
    ).astype(np.float32)


def _combine_kernel(w_ref, x_ref, o_ref):
    """One grid step: o[N+1, bd] = W[N+1, K] @ x[K, bd]; W stays in VMEM."""
    o_ref[...] = jnp.dot(w_ref[...], x_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def coded_combine(
    w: jnp.ndarray, x: jnp.ndarray, *, bd: int = BD, interpret: bool = True
) -> jnp.ndarray:
    """Pallas coded combine: (N+1, K) @ (K, D) -> (N+1, D)."""
    if w.ndim != 2 or x.ndim != 2 or w.shape[1] != x.shape[0]:
        raise ValueError(f"coded_combine shapes {w.shape} @ {x.shape}")
    nw, k = w.shape
    _, d = x.shape
    bd = min(bd, d)
    dp = (d + bd - 1) // bd * bd
    x_p = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, dp - d)))
    out = pl.pallas_call(
        _combine_kernel,
        grid=(dp // bd,),
        in_specs=[
            # W: whole matrix resident every step (index_map pins block 0).
            pl.BlockSpec((nw, k), lambda t: (0, 0)),
            pl.BlockSpec((k, bd), lambda t: (0, t)),
        ],
        out_specs=pl.BlockSpec((nw, bd), lambda t: (0, t)),
        out_shape=jax.ShapeDtypeStruct((nw, dp), jnp.float32),
        interpret=interpret,
    )(w.astype(jnp.float32), x_p)
    return out[:, :d]


def vmem_bytes(nw: int, k: int, bd: int = BD) -> int:
    """Structural VMEM footprint of one grid step (W + X-tile + O-tile)."""
    return 4 * (nw * k + k * bd + nw * bd)
