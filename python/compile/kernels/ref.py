"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Every Pallas kernel in this package has a reference implementation here;
``python/tests/test_kernels.py`` sweeps shapes/dtypes with hypothesis and
asserts allclose against these.
"""

import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Reference for kernels.matmul.matmul: plain f32 GEMM."""
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def coded_combine_ref(w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Reference for kernels.berrut.coded_combine: X-tilde = W . X.

    ``w`` is the (N+1, K) Berrut encode matrix, ``x`` is (K, D) flattened
    query payloads; output is (N+1, D) coded payloads.
    """
    return jnp.dot(w.astype(jnp.float32), x.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def dense_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Reference for the classifier-head dense layer: x.W + b."""
    return matmul_ref(x, w) + b
