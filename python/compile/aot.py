"""AOT export: train (or load cached) models, lower to HLO text, export
datasets + golden vectors + manifest. Runs ONCE under ``make artifacts``;
the rust serving binary is self-contained afterwards.

Interchange is HLO *text*, not serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which the environment's xla_extension
0.5.1 rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Artifacts layout:
    artifacts/
      manifest.json                      # index the rust side parses
      models/{arch}_{dataset}_b{B}.hlo.txt   # weights baked in as constants
      params/{arch}_{dataset}.axp        # trained weights (cache + reuse)
      data/{dataset}_images.bin, _labels.bin # exported test split
      golden/*.bin                       # cross-language test vectors
      encoder_k{K}_s{S}_d{D}.hlo.txt     # Pallas coded-combine artifact
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datasets, models, train
from .kernels import berrut as bk

BATCHES = (1, 128)
TEST_EXPORT_N = 1024


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe interchange).

    ``print_large_constants=True`` is essential: the default HLO printer
    elides big constants, silently dropping the baked model weights from
    the artifact (the model then runs with garbage weights).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_model(arch: str, params, batch: int, hwc) -> str:
    """Lower the hosted model f (softmax soft-label outputs, paper Alg. 2)
    with weights closed over (baked as constants)."""
    h, w, c = hwc

    def fwd(x):
        return (models.apply_soft(arch, params, x, use_pallas=True),)

    spec = jax.ShapeDtypeStruct((batch, h, w, c), jnp.float32)
    return to_hlo_text(jax.jit(fwd).lower(spec))


def lower_encoder(k: int, s: int, e: int, d: int) -> str:
    """Lower the Pallas coded-combine with the (K,S,E) Berrut matrix baked."""
    w = jnp.asarray(bk.encode_matrix(k, s, e))

    def enc(x):
        return (bk.coded_combine(w, x, interpret=True),)

    spec = jax.ShapeDtypeStruct((k, d), jnp.float32)
    return to_hlo_text(jax.jit(enc).lower(spec))


def export_goldens(outdir: str) -> list[dict]:
    """Cross-language golden vectors: rust asserts bit-near agreement."""
    golden_dir = os.path.join(outdir, "golden")
    os.makedirs(golden_dir, exist_ok=True)
    entries = []
    rng = np.random.default_rng(42)
    for (k, s, e) in [(8, 1, 0), (12, 1, 0), (10, 1, 0), (8, 2, 0), (12, 0, 2), (8, 0, 2)]:
        n = (k + s - 1) if e == 0 else (2 * (k + e) + s - 1)
        w = bk.encode_matrix(k, s, e)                      # (n+1, k)
        d = 24
        x = rng.normal(size=(k, d)).astype(np.float32)     # queries
        coded = (w.astype(np.float64) @ x.astype(np.float64)).astype(np.float32)
        wait = k if e == 0 else 2 * (k + e)
        avail = np.sort(rng.choice(n + 1, size=min(wait, n + 1), replace=False))
        # Decode set: when e>0 the decoder excludes e (here arbitrary last e).
        fset = avail[: (k if e == 0 else 2 * k + e)]
        dm = bk.decode_matrix(k, s, e, fset)               # (k, |F|)
        decoded = (dm.astype(np.float64) @ coded[fset].astype(np.float64)).astype(np.float32)
        tag = f"k{k}_s{s}_e{e}"
        datasets.export_binary(os.path.join(golden_dir, f"enc_w_{tag}.bin"), w)
        datasets.export_binary(os.path.join(golden_dir, f"queries_{tag}.bin"), x)
        datasets.export_binary(os.path.join(golden_dir, f"coded_{tag}.bin"), coded)
        datasets.export_binary(
            os.path.join(golden_dir, f"avail_{tag}.bin"), fset.astype(np.int32)
        )
        datasets.export_binary(os.path.join(golden_dir, f"decmat_{tag}.bin"), dm)
        datasets.export_binary(os.path.join(golden_dir, f"decoded_{tag}.bin"), decoded)
        entries.append({"k": k, "s": s, "e": e, "tag": tag, "payload": d})
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--quick", action="store_true",
                    help="tiny training run (CI smoke, low accuracy)")
    args = ap.parse_args()
    outdir = args.out
    for sub in ("models", "params", "data", "golden"):
        os.makedirs(os.path.join(outdir, sub), exist_ok=True)

    manifest: dict = {"version": 1, "models": [], "datasets": [], "golden": [],
                      "encoders": []}

    # ------------------------------------------------ datasets (test split)
    for ds in datasets.DATASETS:
        h, w, c = datasets.shape_of(ds)
        images, labels = datasets.generate(ds, "test", TEST_EXPORT_N)
        img_path, lab_path = f"data/{ds}_images.bin", f"data/{ds}_labels.bin"
        datasets.export_binary(os.path.join(outdir, img_path), images)
        datasets.export_binary(os.path.join(outdir, lab_path), labels)
        manifest["datasets"].append({
            "name": ds, "images": img_path, "labels": lab_path,
            "count": TEST_EXPORT_N, "height": h, "width": w, "channels": c,
            "num_classes": datasets.NUM_CLASSES,
        })
        print(f"[aot] dataset {ds}: exported {TEST_EXPORT_N} test samples")

    # ------------------------------------------------ models: train + lower
    epochs = 1 if args.quick else train.EPOCHS
    train_n = 512 if args.quick else train.TRAIN_N
    for arch, ds in train.PLAN:
        hwc = datasets.shape_of(ds)
        ppath = os.path.join(outdir, "params", f"{arch}_{ds}.axp")
        apath_acc = ppath + ".acc"
        if os.path.exists(ppath) and os.path.exists(apath_acc):
            params = models.load_params(ppath)
            base_acc = float(open(apath_acc).read())
            print(f"[aot] {arch}/{ds}: cached params (base acc {base_acc:.4f})")
        else:
            t0 = time.time()
            params, base_acc = train.train_one(
                arch, ds, epochs=epochs, train_n=train_n, verbose=not args.quick
            )
            models.save_params(ppath, params)
            with open(apath_acc, "w") as f:
                f.write(f"{base_acc}")
            print(f"[aot] {arch}/{ds}: trained, base acc {base_acc:.4f} "
                  f"({time.time()-t0:.0f}s)")
        for batch in BATCHES:
            hlo = lower_model(arch, params, batch, hwc)
            rel = f"models/{arch}_{ds}_b{batch}.hlo.txt"
            with open(os.path.join(outdir, rel), "w") as f:
                f.write(hlo)
            manifest["models"].append({
                "arch": arch, "dataset": ds, "batch": batch, "path": rel,
                "input": [batch, *hwc], "num_classes": datasets.NUM_CLASSES,
                "base_test_acc": base_acc,
                "param_count": models.param_count(params),
            })
        print(f"[aot] {arch}/{ds}: lowered batches {BATCHES}")

    # ------------------------------------------------ Pallas encoder artifact
    for (k, s, ds) in [(8, 1, "syncifar")]:
        h, w, c = datasets.shape_of(ds)
        d = h * w * c
        hlo = lower_encoder(k, s, 0, d)
        rel = f"encoder_k{k}_s{s}_d{d}.hlo.txt"
        with open(os.path.join(outdir, rel), "w") as f:
            f.write(hlo)
        manifest["encoders"].append({
            "k": k, "s": s, "e": 0, "payload": d, "path": rel,
            "workers": k + s,
        })
        print(f"[aot] encoder k={k} s={s} d={d} lowered")

    # ------------------------------------------------ goldens + manifest
    manifest["golden"] = export_goldens(outdir)
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest written: {len(manifest['models'])} model artifacts")


if __name__ == "__main__":
    main()
