"""Synthetic stand-ins for MNIST / Fashion-MNIST / CIFAR-10.

No dataset downloads are possible in this environment, so we substitute
deterministic procedural datasets with the same shapes and class count
(documented in DESIGN.md §3):

- ``synmnist``   28x28x1, 10 classes — stroke-rendered digit-like glyphs.
- ``synfashion`` 28x28x1, 10 classes — textured garment-like silhouettes.
- ``syncifar``   32x32x3, 10 classes — colored shape/texture scenes.

Each sample is a class template (fixed per class, seeded) under a random
affine jitter, amplitude scaling, distractor field and pixel noise — enough
variability that a CNN must actually learn, while staying learnable to
high accuracy in a couple of build-time epochs. ApproxIFER's behaviour
depends on the hosted model being a trained nonlinear classifier evaluated
at off-manifold coded points, which these datasets exercise identically to
the originals.

Everything is generated with a deterministic numpy Generator per
(dataset, split), so the exported test set is bit-stable across runs.
"""

from __future__ import annotations

import zlib

import numpy as np


def _stable_seed(*parts: object) -> int:
    """Process-stable seed (python's hash() is randomized per process)."""
    return zlib.crc32("/".join(str(p) for p in parts).encode())

DATASETS = ("synmnist", "synfashion", "syncifar")
NUM_CLASSES = 10


def shape_of(name: str) -> tuple[int, int, int]:
    """(H, W, C) of one sample."""
    if name == "syncifar":
        return (32, 32, 3)
    if name in ("synmnist", "synfashion"):
        return (28, 28, 1)
    raise ValueError(f"unknown dataset {name!r}")


def _smooth_field(rng: np.random.Generator, h: int, w: int, cutoff: int) -> np.ndarray:
    """Low-frequency random field in [-1, 1] via truncated 2-D Fourier basis."""
    field = np.zeros((h, w))
    ys = np.arange(h)[:, None] / h
    xs = np.arange(w)[None, :] / w
    for ky in range(cutoff):
        for kx in range(cutoff):
            amp = rng.normal() / (1.0 + ky + kx)
            phase_y, phase_x = rng.uniform(0, 2 * np.pi, size=2)
            field += amp * np.cos(2 * np.pi * ky * ys + phase_y) * np.cos(
                2 * np.pi * kx * xs + phase_x
            )
    m = np.abs(field).max() + 1e-9
    return field / m


def _digit_glyph(c: int, h: int, w: int) -> np.ndarray:
    """Seven-segment-style glyph for class c (digit-like strokes)."""
    seg = {
        0: "abcdef", 1: "bc", 2: "abged", 3: "abgcd", 4: "fgbc",
        5: "afgcd", 6: "afgedc", 7: "abc", 8: "abcdefg", 9: "abcfgd",
    }[c]
    img = np.zeros((h, w))
    t = max(2, h // 10)  # stroke thickness
    x0, x1 = w // 4, 3 * w // 4
    y0, y1, y2 = h // 6, h // 2, 5 * h // 6
    if "a" in seg:
        img[y0 - t // 2 : y0 + t // 2 + 1, x0:x1] = 1.0
    if "g" in seg:
        img[y1 - t // 2 : y1 + t // 2 + 1, x0:x1] = 1.0
    if "d" in seg:
        img[y2 - t // 2 : y2 + t // 2 + 1, x0:x1] = 1.0
    if "f" in seg:
        img[y0:y1, x0 - t // 2 : x0 + t // 2 + 1] = 1.0
    if "b" in seg:
        img[y0:y1, x1 - t // 2 : x1 + t // 2 + 1] = 1.0
    if "e" in seg:
        img[y1:y2, x0 - t // 2 : x0 + t // 2 + 1] = 1.0
    if "c" in seg:
        img[y1:y2, x1 - t // 2 : x1 + t // 2 + 1] = 1.0
    return img


def _silhouette(c: int, h: int, w: int) -> np.ndarray:
    """Garment-like blocky silhouette masks, one per class."""
    img = np.zeros((h, w))
    ys = np.arange(h)[:, None]
    xs = np.arange(w)[None, :]
    cy, cx = h / 2, w / 2
    if c % 5 == 0:  # "shirt": torso + arms
        img[(ys > h * 0.3) & (ys < h * 0.9) & (xs > w * 0.3) & (xs < w * 0.7)] = 1
        img[(ys > h * 0.3) & (ys < h * 0.55) & (xs > w * 0.1) & (xs < w * 0.9)] = 1
    elif c % 5 == 1:  # "trouser": two legs
        img[(ys > h * 0.15) & (xs > w * 0.3) & (xs < w * 0.45)] = 1
        img[(ys > h * 0.15) & (xs > w * 0.55) & (xs < w * 0.7)] = 1
        img[(ys > h * 0.15) & (ys < h * 0.35) & (xs > w * 0.3) & (xs < w * 0.7)] = 1
    elif c % 5 == 2:  # "bag": trapezoid + handle
        img[(ys > h * 0.45) & (ys < h * 0.85) & (xs > w * 0.2) & (xs < w * 0.8)] = 1
        rr = ((ys - h * 0.42) ** 2 + (xs - cx) ** 2) ** 0.5
        img[(rr > h * 0.12) & (rr < h * 0.2) & (ys < h * 0.45)] = 1
    elif c % 5 == 3:  # "dress": triangle
        width = (ys / h) * w * 0.45
        img[(ys > h * 0.2) & (np.abs(xs - cx) < width)] = 1
    else:  # "shoe": L-shape
        img[(ys > h * 0.55) & (ys < h * 0.8) & (xs > w * 0.15) & (xs < w * 0.85)] = 1
        img[(ys > h * 0.3) & (ys < h * 0.8) & (xs > w * 0.15) & (xs < w * 0.4)] = 1
    if c >= 5:  # second family: same silhouettes, hollowed
        inner = np.zeros_like(img)
        inner[2:-2, 2:-2] = img[2:-2, 2:-2] * (img[:-4, 2:-2] * img[4:, 2:-2] > 0)
        img = img - 0.6 * inner
    return img


def _class_template(name: str, c: int) -> np.ndarray:
    """(H, W, C) template for class c of a dataset — deterministic."""
    h, w, ch = shape_of(name)
    rng = np.random.default_rng(_stable_seed(name, "template", c))
    if name == "synmnist":
        base = _digit_glyph(c, h, w)[..., None]
    elif name == "synfashion":
        tex = 0.25 * _smooth_field(rng, h, w, 4)
        base = (_silhouette(c, h, w) * (0.8 + tex))[..., None]
    else:  # syncifar: colored shape over textured background
        mask = _silhouette(c % 10, h, w)
        color = rng.uniform(0.3, 1.0, size=3)
        tex = np.stack([_smooth_field(rng, h, w, 3) for _ in range(3)], axis=-1)
        base = mask[..., None] * color[None, None, :] + 0.3 * tex
    return base.astype(np.float32)


_TEMPLATE_CACHE: dict[tuple[str, int], np.ndarray] = {}


def class_template(name: str, c: int) -> np.ndarray:
    key = (name, c)
    if key not in _TEMPLATE_CACHE:
        _TEMPLATE_CACHE[key] = _class_template(name, c)
    return _TEMPLATE_CACHE[key]


def _jitter(rng: np.random.Generator, img: np.ndarray) -> np.ndarray:
    """Random integer shift plus horizontal flip (syncifar only upstream)."""
    dy, dx = rng.integers(-3, 4, size=2)
    out = np.roll(np.roll(img, dy, axis=0), dx, axis=1)
    return out


def generate(name: str, split: str, count: int) -> tuple[np.ndarray, np.ndarray]:
    """Generate (images[count,H,W,C] float32 in ~[0,1.5], labels[count] int32).

    Deterministic per (name, split): train/test are disjoint streams.
    """
    h, w, ch = shape_of(name)
    rng = np.random.default_rng(_stable_seed(name, split, "v1"))
    images = np.zeros((count, h, w, ch), dtype=np.float32)
    labels = rng.integers(0, NUM_CLASSES, size=count).astype(np.int32)
    distractor_pool = [
        _smooth_field(np.random.default_rng(1000 + i), h, w, 3) for i in range(8)
    ]
    for i in range(count):
        c = int(labels[i])
        base = class_template(name, c)
        amp = rng.uniform(0.7, 1.3)
        x = amp * _jitter(rng, base)
        d = distractor_pool[rng.integers(0, len(distractor_pool))][..., None]
        x = x + 0.15 * rng.uniform() * d
        x = x + rng.normal(0, 0.08, size=x.shape)
        images[i] = np.clip(x, -0.5, 1.6)
    return images, labels


def export_binary(path: str, arr: np.ndarray) -> None:
    """Write the simple tensor container the rust side reads:
    magic 'AXT1' | u32 ndim | u32 dims[ndim] | f32/i32 data (LE)."""
    with open(path, "wb") as f:
        f.write(b"AXT1")
        dims = np.array(arr.shape, dtype="<u4")
        f.write(np.array([arr.ndim], dtype="<u4").tobytes())
        f.write(dims.tobytes())
        if arr.dtype == np.float32:
            f.write(arr.astype("<f4").tobytes())
        elif arr.dtype == np.int32:
            f.write(arr.astype("<i4").tobytes())
        else:
            raise ValueError(f"unsupported dtype {arr.dtype}")
