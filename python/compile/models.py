"""Layer-2: the hosted models `f` — pure-JAX CNN classifiers.

Scaled-down counterparts of the paper's architectures (DESIGN.md §3):
``lenet5``, ``vgg_s`` (VGG-16-style conv blocks), ``resnet18_s`` /
``resnet34_s`` (basic residual blocks), ``densenet_s`` (dense blocks +
transition), ``googlenet_s`` (inception branches). All are BN-free with He
init (keeps the build-time training loop stateless) and end in a dense
classifier head that runs on the Layer-1 Pallas GEMM when
``use_pallas=True`` (the AOT export path), or plain jnp during training.

Every model is ``init(seed, dataset) -> params`` (nested dict of arrays)
plus ``apply(arch, params, x, use_pallas) -> logits`` with
``x: (B, H, W, C)`` NHWC float32 and 10 logits out.
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp  # noqa: F401  (jax.nn used in apply_soft)
import numpy as np
from jax import lax

from . import datasets
from .kernels import matmul as pallas_mm

ARCHS = ("lenet5", "vgg_s", "resnet18_s", "resnet34_s", "densenet_s", "googlenet_s")

Params = Dict[str, Any]


# ---------------------------------------------------------------- layers ---

def _he(rng: np.random.Generator, shape, fan_in) -> jnp.ndarray:
    return jnp.asarray(
        rng.normal(0.0, math.sqrt(2.0 / fan_in), size=shape).astype(np.float32)
    )


def conv_init(rng, kh, kw, cin, cout) -> Params:
    return {
        "w": _he(rng, (kh, kw, cin, cout), kh * kw * cin),
        "b": jnp.zeros((cout,), jnp.float32),
    }


def conv(p: Params, x: jnp.ndarray, stride: int = 1, padding: str = "SAME") -> jnp.ndarray:
    y = lax.conv_general_dilated(
        x, p["w"], (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"]


def dense_init(rng, din, dout) -> Params:
    return {
        "w": _he(rng, (din, dout), din),
        "b": jnp.zeros((dout,), jnp.float32),
    }


def dense(p: Params, x: jnp.ndarray, use_pallas: bool) -> jnp.ndarray:
    if use_pallas:
        return pallas_mm.dense(x, p["w"], p["b"], interpret=True)
    return x @ p["w"] + p["b"]


def max_pool(x: jnp.ndarray, k: int = 2) -> jnp.ndarray:
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, k, k, 1), (1, k, k, 1), "VALID"
    )


def avg_pool(x: jnp.ndarray, k: int = 2) -> jnp.ndarray:
    s = lax.reduce_window(x, 0.0, lax.add, (1, k, k, 1), (1, k, k, 1), "VALID")
    return s / (k * k)


def gap(x: jnp.ndarray) -> jnp.ndarray:
    return x.mean(axis=(1, 2))


def relu(x):
    return jnp.maximum(x, 0.0)


# ------------------------------------------------------------------ zoo ----

def _rng_of(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def init_lenet5(seed: int, cin: int, hw: int = 28) -> Params:
    r = _rng_of(seed)
    flat = (hw // 4) * (hw // 4) * 16  # two 2x2 pools then flatten
    return {
        "c1": conv_init(r, 5, 5, cin, 6),
        "c2": conv_init(r, 5, 5, 6, 16),
        "f1": dense_init(r, flat, 120),
        "f2": dense_init(r, 120, 84),
        "head": dense_init(r, 84, 10),
    }


def apply_lenet5(p: Params, x, use_pallas: bool):
    x = relu(conv(p["c1"], x))
    x = max_pool(x)
    x = relu(conv(p["c2"], x))
    x = max_pool(x)
    x = x.reshape(x.shape[0], -1)
    x = relu(dense(p["f1"], x, False))
    x = relu(dense(p["f2"], x, False))
    return dense(p["head"], x, use_pallas)


_VGG_PLAN = ((16, 2), (32, 2), (64, 2))  # (width, convs) per block — VGG-16 style


def init_vgg_s(seed: int, cin: int) -> Params:
    r = _rng_of(seed)
    p: Params = {}
    c = cin
    for bi, (width, convs) in enumerate(_VGG_PLAN):
        for ci in range(convs):
            p[f"b{bi}c{ci}"] = conv_init(r, 3, 3, c, width)
            c = width
    p["fc"] = dense_init(r, c, 64)
    p["head"] = dense_init(r, 64, 10)
    return p


def apply_vgg_s(p: Params, x, use_pallas: bool):
    for bi, (width, convs) in enumerate(_VGG_PLAN):
        for ci in range(convs):
            x = relu(conv(p[f"b{bi}c{ci}"], x))
        x = max_pool(x)
    x = gap(x)
    x = relu(dense(p["fc"], x, False))
    return dense(p["head"], x, use_pallas)


def _init_resnet(seed: int, cin: int, blocks_per_stage) -> Params:
    r = _rng_of(seed)
    widths = (16, 32, 64)
    p: Params = {"stem": conv_init(r, 3, 3, cin, widths[0])}
    c = widths[0]
    for si, width in enumerate(widths):
        for bi in range(blocks_per_stage[si]):
            stride = 2 if (si > 0 and bi == 0) else 1
            p[f"s{si}b{bi}c1"] = conv_init(r, 3, 3, c, width)
            p[f"s{si}b{bi}c2"] = conv_init(r, 3, 3, width, width)
            if stride != 1 or c != width:
                p[f"s{si}b{bi}proj"] = conv_init(r, 1, 1, c, width)
            c = width
    p["head"] = dense_init(r, c, 10)
    return p


def _apply_resnet(p: Params, x, blocks_per_stage, use_pallas: bool):
    x = relu(conv(p["stem"], x))
    widths = (16, 32, 64)
    c = widths[0]
    for si, width in enumerate(widths):
        for bi in range(blocks_per_stage[si]):
            stride = 2 if (si > 0 and bi == 0) else 1
            h = relu(conv(p[f"s{si}b{bi}c1"], x, stride=stride))
            h = conv(p[f"s{si}b{bi}c2"], h)
            if f"s{si}b{bi}proj" in p:
                x = conv(p[f"s{si}b{bi}proj"], x, stride=stride)
            x = relu(x + h)
            c = width
    return dense(p["head"], gap(x), use_pallas)


def init_resnet18_s(seed: int, cin: int) -> Params:
    return _init_resnet(seed, cin, (2, 2, 2))


def apply_resnet18_s(p, x, use_pallas):
    return _apply_resnet(p, x, (2, 2, 2), use_pallas)


def init_resnet34_s(seed: int, cin: int) -> Params:
    return _init_resnet(seed, cin, (3, 4, 3))


def apply_resnet34_s(p, x, use_pallas):
    return _apply_resnet(p, x, (3, 4, 3), use_pallas)


_DN_GROWTH, _DN_LAYERS = 12, (4, 4)


def init_densenet_s(seed: int, cin: int) -> Params:
    r = _rng_of(seed)
    p: Params = {"stem": conv_init(r, 3, 3, cin, 16)}
    c = 16
    for bi, nlayers in enumerate(_DN_LAYERS):
        for li in range(nlayers):
            p[f"b{bi}l{li}"] = conv_init(r, 3, 3, c, _DN_GROWTH)
            c += _DN_GROWTH
        p[f"t{bi}"] = conv_init(r, 1, 1, c, c // 2)
        c = c // 2
    p["head"] = dense_init(r, c, 10)
    return p


def apply_densenet_s(p: Params, x, use_pallas: bool):
    x = relu(conv(p["stem"], x))
    for bi, nlayers in enumerate(_DN_LAYERS):
        for li in range(nlayers):
            y = relu(conv(p[f"b{bi}l{li}"], x))
            x = jnp.concatenate([x, y], axis=-1)
        x = relu(conv(p[f"t{bi}"], x))
        x = avg_pool(x)
    return dense(p["head"], gap(x), use_pallas)


def _init_inception(r, cin, n1, n3r, n3, n5r, n5, npj) -> Params:
    return {
        "p1": conv_init(r, 1, 1, cin, n1),
        "p3r": conv_init(r, 1, 1, cin, n3r),
        "p3": conv_init(r, 3, 3, n3r, n3),
        "p5r": conv_init(r, 1, 1, cin, n5r),
        "p5": conv_init(r, 5, 5, n5r, n5),
        "pp": conv_init(r, 1, 1, cin, npj),
    }


def _apply_inception(p: Params, x) -> jnp.ndarray:
    b1 = relu(conv(p["p1"], x))
    b3 = relu(conv(p["p3"], relu(conv(p["p3r"], x))))
    b5 = relu(conv(p["p5"], relu(conv(p["p5r"], x))))
    pooled = lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 3, 3, 1), (1, 1, 1, 1), "SAME"
    )
    bp = relu(conv(p["pp"], pooled))
    return jnp.concatenate([b1, b3, b5, bp], axis=-1)


def init_googlenet_s(seed: int, cin: int) -> Params:
    r = _rng_of(seed)
    p: Params = {"stem": conv_init(r, 3, 3, cin, 16)}
    p["inc1"] = _init_inception(r, 16, 8, 8, 16, 4, 8, 8)     # -> 40
    p["inc2"] = _init_inception(r, 40, 16, 12, 24, 4, 12, 12)  # -> 64
    p["head"] = dense_init(r, 64, 10)
    return p


def apply_googlenet_s(p: Params, x, use_pallas: bool):
    x = relu(conv(p["stem"], x))
    x = max_pool(x)
    x = _apply_inception(p["inc1"], x)
    x = max_pool(x)
    x = _apply_inception(p["inc2"], x)
    return dense(p["head"], gap(x), use_pallas)


_INIT = {
    "lenet5": init_lenet5,
    "vgg_s": init_vgg_s,
    "resnet18_s": init_resnet18_s,
    "resnet34_s": init_resnet34_s,
    "densenet_s": init_densenet_s,
    "googlenet_s": init_googlenet_s,
}
_APPLY = {
    "lenet5": apply_lenet5,
    "vgg_s": apply_vgg_s,
    "resnet18_s": apply_resnet18_s,
    "resnet34_s": apply_resnet34_s,
    "densenet_s": apply_densenet_s,
    "googlenet_s": apply_googlenet_s,
}


def init(arch: str, dataset: str, seed: int = 0) -> Params:
    """Initialize parameters for an architecture on a dataset."""
    h, _, cin = datasets.shape_of(dataset)
    if arch == "lenet5":
        return init_lenet5(seed, cin, hw=h)
    return _INIT[arch](seed, cin)


def apply(arch: str, params: Params, x: jnp.ndarray, use_pallas: bool = False):
    """Forward pass: (B, H, W, C) -> (B, 10) logits."""
    return _APPLY[arch](params, x, use_pallas)


def apply_soft(arch: str, params: Params, x: jnp.ndarray, use_pallas: bool = False):
    """Forward pass ending in softmax: (B, H, W, C) -> (B, 10) soft labels.

    This is the `f` the serving system hosts (paper Algorithm 2 calls the
    coordinates of f(X-tilde) "soft labels"): bounded [0,1] outputs are what
    makes Berrut decoding and the sigma in {1,10,100} Byzantine experiments
    behave as in the paper — raw logits from a converged classifier are
    saturated (|logit| ~ 50) and interpolate poorly.
    """
    return jax.nn.softmax(apply(arch, params, x, use_pallas), axis=-1)


def _flatten(params: Params, prefix: str = "") -> list[tuple[str, np.ndarray]]:
    """Flatten an arbitrarily nested dict-of-arrays to (path, array) pairs."""
    out: list[tuple[str, np.ndarray]] = []
    for k in sorted(params):
        v = params[k]
        path = f"{prefix}{k}"
        if isinstance(v, dict):
            out.extend(_flatten(v, prefix=path + "/"))
        else:
            out.append((path, np.asarray(v)))
    return out


def param_count(params: Params) -> int:
    return sum(int(np.prod(a.shape)) for _, a in _flatten(params))


# ------------------------------------------------------- (de)serialization -

def save_params(path: str, params: Params) -> None:
    """Flat custom container (no pickle): repeated (name, shape, f32 data)."""
    with open(path, "wb") as f:
        f.write(b"AXP1")
        flat = _flatten(params)
        f.write(np.array([len(flat)], dtype="<u4").tobytes())
        for name, arr in flat:
            nb = name.encode()
            f.write(np.array([len(nb)], dtype="<u4").tobytes())
            f.write(nb)
            f.write(np.array([arr.ndim], dtype="<u4").tobytes())
            f.write(np.array(arr.shape, dtype="<u4").tobytes())
            f.write(arr.astype("<f4").tobytes())


def load_params(path: str) -> Params:
    with open(path, "rb") as f:
        assert f.read(4) == b"AXP1"
        (count,) = np.frombuffer(f.read(4), "<u4")
        params: Params = {}
        for _ in range(int(count)):
            (nlen,) = np.frombuffer(f.read(4), "<u4")
            name = f.read(int(nlen)).decode()
            (ndim,) = np.frombuffer(f.read(4), "<u4")
            shape = tuple(int(d) for d in np.frombuffer(f.read(4 * int(ndim)), "<u4"))
            size = int(np.prod(shape)) if ndim else 1
            data = np.frombuffer(f.read(4 * size), "<f4").reshape(shape)
            node = params
            parts = name.split("/")
            for part in parts[:-1]:
                node = node.setdefault(part, {})
            node[parts[-1]] = jnp.asarray(data.copy())
        return params
